"""Differential parity harness for the vectorized event-calendar loop
(ISSUE 9): ``ClusterRuntime(..., fast=True)`` must produce field-exact
identical :class:`SimMetrics` to the legacy oracle loop (``fast=False``)
on every seeded scenario family — same completions, misses, fan-weighted
drops and drop reasons, same latency list in the same append order, same
per-app / per-domain / transition-window sub-ledgers.

The contract includes RNG draw ordering: the fast loop must consume the
shared generator in exactly the legacy order (arrival processes, the
SimBackend's lognormal service draws, the per-(request, successor)
fan-out coins), so ANY divergence — a reordered event, a skipped poll
that wasn't a no-op, a drop evaluated at the wrong instant — shows up as
a field diff.  The diff oracle is the same recursive comparator the
determinism sanitizer uses (``repro.runtime.metrics.diff_metrics``).

Families covered: poisson / diurnal / burst / trace-replay arrivals,
failure + capacity schedules, correlated domain failures, spot
preemption drains, a mid-run ``TransitionEvent``, multi-app co-location,
the ladder-monitored chaos testbed (EmergencyReplanner + ladder), and
the full 23-case pinned SLO-breaking fuzzer corpus."""
import json
import os

import pytest

from repro.chaos import DegradationLadder, EmergencyReplanner
from repro.chaos.fuzz import case_from_seed
from repro.core.apps import get_app
from repro.core.frontend import Frontend
from repro.core.milp import Planner
from repro.core.profiler import Profiler
from repro.core.trace import diurnal_trace
from repro.hwspec import chaos_cluster
from repro.reconfig import TransitionPlanner
from repro.runtime import (ClusterRuntime, DomainFailureEvent,
                           FailureEvent, PoissonArrivals, PreemptionEvent,
                           Scenario, SimBackend)
from repro.runtime.metrics import diff_metrics
from repro.runtime.scenario import CapacityEvent, TransitionEvent

KW = dict(max_tuples_per_task=32, bb_nodes=8, bb_time_s=3.0)
PINS = os.path.join(os.path.dirname(__file__), "chaos_pins.json")


@pytest.fixture(scope="module")
def fleet():
    cluster = chaos_cluster()
    graph = get_app("social_media")
    prof = Profiler(graph, cluster=cluster)
    planner = Planner(graph, prof, s_avail=cluster.total_units, **KW)
    return cluster, graph, prof, planner


@pytest.fixture(scope="module")
def cfg15(fleet):
    _, _, _, planner = fleet
    planner.dead_units = {}
    cfg = planner.plan(15.0)
    assert cfg is not None
    return cfg


@pytest.fixture(scope="module")
def cfg30(fleet):
    _, _, _, planner = fleet
    planner.dead_units = {}
    cfg = planner.plan(30.0)
    assert cfg is not None
    return cfg


def assert_parity(fleet, cfg, scenario, seed=0, mk_extra=None):
    """Run ``scenario`` through the legacy oracle and the fast loop on
    fresh runtimes and assert field-exact SimMetrics equality.

    ``mk_extra`` builds FRESH keyword extras (monitor / ladder / hooks)
    per run — those objects are stateful, so sharing one instance across
    the two runs would itself break parity."""
    cluster, graph, _, _ = fleet
    out = []
    for fast in (False, True):
        extra = mk_extra() if mk_extra is not None else {}
        rt = ClusterRuntime(graph, cfg, SimBackend(), seed=seed,
                            cluster=cluster, fast=fast, **extra)
        out.append(rt.run(scenario))
    ml, mf = out
    d = diff_metrics(ml, mf)
    assert not d, (f"fast loop diverged from legacy oracle on "
                   f"{scenario.name!r} ({len(d)} fields):\n"
                   + "\n".join(d[:20]))
    assert mf.completions > 0, f"{scenario.name!r}: degenerate scenario"
    return mf


# ---------------------------------------------------------------------------
# arrival families
# ---------------------------------------------------------------------------
def test_parity_poisson(fleet, cfg15):
    assert_parity(fleet, cfg15,
                  Scenario.poisson(12.0, duration_s=6.0, warmup_s=1.0))


def test_parity_poisson_saturated(fleet, cfg15):
    """Overload: deep queues exercise the O(1) drop guards against the
    legacy per-event early-drop scan — every drop must match exactly."""
    m = assert_parity(
        fleet, cfg15, Scenario.poisson(45.0, duration_s=6.0, warmup_s=1.0))
    assert m.dropped > 0, "saturation scenario never tripped a drop"


def test_parity_diurnal(fleet, cfg15):
    assert_parity(fleet, cfg15,
                  Scenario.diurnal(18.0, duration_s=6.0, warmup_s=1.0,
                                   seed=2),
                  seed=3)


def test_parity_burst(fleet, cfg15):
    assert_parity(fleet, cfg15,
                  Scenario.burst(6.0, 24.0, duration_s=6.0, warmup_s=1.0))


def test_parity_trace_replay(fleet, cfg15):
    tr = diurnal_trace(seed=5).scaled_to_max(14.0)
    assert_parity(fleet, cfg15,
                  Scenario.replay(tr, duration_s=6.0, warmup_s=1.0),
                  seed=7)


# ---------------------------------------------------------------------------
# failure / capacity / chaos schedules
# ---------------------------------------------------------------------------
def test_parity_failures_and_capacity(fleet, cfg15):
    sc = (Scenario.poisson(12.0, duration_s=8.0, warmup_s=1.0)
          .with_failures(FailureEvent(at_s=2.0, task="classify", count=1))
          .with_capacity(CapacityEvent(at_s=3.0, task="classify", delta=2),
                         CapacityEvent(at_s=6.0, task="classify",
                                       delta=-1)))
    assert_parity(fleet, cfg15, sc)


def test_parity_domain_failure(fleet, cfg15):
    sc = (Scenario.poisson(12.0, duration_s=8.0, warmup_s=1.0)
          .with_chaos(DomainFailureEvent(at_s=2.5, domain="r0")))
    m = assert_parity(fleet, cfg15, sc)
    assert "r0" in m.by_domain


def test_parity_preemption(fleet, cfg15):
    sc = (Scenario.poisson(12.0, duration_s=8.0, warmup_s=1.0)
          .with_chaos(PreemptionEvent(at_s=2.0, pool="spot",
                                      notice_s=1.5)))
    assert_parity(fleet, cfg15, sc)


# ---------------------------------------------------------------------------
# live reconfiguration
# ---------------------------------------------------------------------------
def test_parity_midrun_transition(fleet, cfg15, cfg30):
    cluster, graph, _, _ = fleet
    tr = TransitionPlanner(cluster, graph).plan(cfg15, cfg30)
    assert not tr.is_empty
    sc = (Scenario.step_change(12.0, 28.0, duration_s=10.0, warmup_s=0.0,
                               switch_frac=0.5)
          .with_transitions(TransitionEvent(at_s=5.0, plan=tr)))
    m = assert_parity(fleet, cfg15, sc)
    assert m.window is not None       # the window ledger matched too


# ---------------------------------------------------------------------------
# multi-app co-location
# ---------------------------------------------------------------------------
def test_parity_multi_app():
    apps = {}
    for name in ("social_media", "traffic_analysis"):
        g = get_app(name)
        cfg = Planner(g, Profiler(g), s_avail=64, max_tuples_per_task=32,
                      bb_nodes=4, bb_time_s=1.0).plan(20.0)
        assert cfg is not None
        apps[name] = (g, cfg)
    sc = Scenario.multi({n: PoissonArrivals(15.0) for n in apps},
                        duration_s=6.0, warmup_s=1.0)
    out = []
    for fast in (False, True):
        rt = ClusterRuntime.multi(apps, SimBackend(), seed=1, fast=fast)
        out.append(rt.run(sc))
    d = diff_metrics(*out)
    assert not d, ("multi-app fast/legacy divergence:\n"
                   + "\n".join(d[:20]))
    assert set(out[1].by_app) == set(apps)


# ---------------------------------------------------------------------------
# ladder-monitored chaos testbed
# ---------------------------------------------------------------------------
def test_parity_ladder_monitored_chaos(fleet, cfg30):
    """The full protection stack mid-run: a domain failure under load
    with the EmergencyReplanner re-planning mid-bin (through the PR-5
    transition machinery) and the degradation ladder shedding at the
    door.  Monitor and ladder are stateful, so each run gets fresh
    instances."""
    cluster, graph, prof, _ = fleet

    def mk_extra():
        epl = Planner(graph, prof, s_avail=cluster.total_units,
                      stickiness=0.05, **KW)
        mon = EmergencyReplanner(Frontend(graph), planner=epl,
                                 reconfig=TransitionPlanner(cluster, graph),
                                 planned_for_rps=30.0)
        return {"monitor": mon, "ladder": DegradationLadder(profiler=prof)}

    sc = (Scenario.poisson(30.0, duration_s=10.0, warmup_s=1.0)
          .with_chaos(DomainFailureEvent(at_s=3.0, domain="r0")))
    assert_parity(fleet, cfg30, sc, mk_extra=mk_extra)


# ---------------------------------------------------------------------------
# the pinned SLO-breaking fuzzer corpus — all 23 cases
# ---------------------------------------------------------------------------
def _pin_cases():
    with open(PINS) as f:
        pins = json.load(f)
    return [case_from_seed(meta["seed"])
            for _, meta in sorted(pins["cases"].items())]


def test_parity_all_chaos_pins(fleet):
    """Every pinned SLO-breaking fuzzer case replays field-exact
    identically on the fast loop — the chaos regression corpus gates
    the rewrite (ISSUE 9 satellite)."""
    cluster, graph, _, planner = fleet
    cases = _pin_cases()
    assert len(cases) >= 20, f"pin corpus shrank: {len(cases)}"
    plans = {}
    checked = 0
    for case in cases:
        if case.rate_rps not in plans:
            planner.dead_units = {}
            plans[case.rate_rps] = planner.plan(float(case.rate_rps))
        cfg = plans[case.rate_rps]
        if cfg is None:       # infeasible demand: nothing to replay
            continue
        sc = case.scenario()
        out = []
        for fast in (False, True):
            rt = ClusterRuntime(graph, cfg, SimBackend(), seed=case.seed,
                                cluster=cluster, fast=fast)
            out.append(rt.run(sc))
        d = diff_metrics(*out)
        assert not d, (f"pin {case.case_id} diverged ({len(d)} fields):\n"
                       + "\n".join(d[:20]))
        checked += 1
    assert checked >= 20, f"only {checked} pins replayed"
