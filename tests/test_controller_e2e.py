"""Controller + frontend + trace integration: the paper's serving loop at
small scale, including failure-shrunken capacity and elasticity."""
import numpy as np
import pytest

from repro.core import Controller, register
from repro.core.apps import get_app
from repro.core.milp import FeatureSet
from repro.core.trace import DemandTrace, diurnal_trace, predict_demand
from repro.core.frontend import Frontend


@pytest.fixture(scope="module")
def ctl(social_profiler):
    g, prof = social_profiler
    return Controller(g, prof, s_avail=64,
                      planner_kwargs=dict(max_tuples_per_task=32,
                                          bb_nodes=4, bb_time_s=1.0))


def test_trace_properties():
    t = diurnal_trace(seed=1, bins=288)
    assert t.num_bins == 288
    assert t.rps.max() == pytest.approx(1.0)
    t2 = diurnal_trace(seed=1, bins=288)
    np.testing.assert_array_equal(t.rps, t2.rps)   # deterministic
    scaled = t.scaled_to_max(500.0)
    assert scaled.rps.max() == pytest.approx(500.0)


def test_predictor_mean_of_last_five_plus_slack():
    hist = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0]
    assert predict_demand(hist, slack=0.05) == pytest.approx(
        np.mean(hist[-5:]) * 1.05)


def test_controller_trace_loop(ctl):
    trace = diurnal_trace(seed=2, bins=6).scaled_to_max(120.0)
    reports = [ctl.step(i, float(r), sim_seconds=6.0, seed=i)
               for i, r in enumerate(trace.rps)]
    # all bins served with low violations
    for rep in reports:
        assert rep.violation_rate < 0.05, rep
        assert rep.slices_used <= 64
    # at least one replan over a 3x demand range
    assert any(r.replanned for r in reports)
    # MILP time in the paper's envelope (2-20 s upper bound)
    assert all(t < 20_000 for t in ctl.milp_times_ms)


def test_controller_capacity_shrink(social_profiler):
    """Failure handling: re-solve with dead chips removed still serves."""
    g, prof = social_profiler
    ctl = Controller(g, prof, s_avail=64,
                     planner_kwargs=dict(max_tuples_per_task=32,
                                         bb_nodes=4, bb_time_s=1.0))
    rep = ctl.step(0, 40.0, sim_seconds=6.0, dead_chips=32)
    assert rep.slices_used <= 32
    assert rep.violation_rate < 0.05


def test_max_serviceable_demand_positive(ctl):
    cap = ctl.max_serviceable_demand()
    assert cap > 10.0


def test_frontend_deadlines_and_binning():
    g = get_app("ar_assistant")
    fe = Frontend(g, bin_seconds=10.0)
    m = fe.submit(1.0)
    # depth-3 app: SLO + 2 hops x 10 ms
    assert m.deadline_s == pytest.approx(1.0 + (1550 + 20) / 1e3)
    for t in (2.0, 3.0, 11.0):
        fe.submit(t)
    assert fe.observed_demand()[0] == pytest.approx(3 / 10.0)
    assert fe.should_replan(planned_for_rps=100.0)   # big drift
    assert not fe.should_replan(planned_for_rps=0.1)


def test_controller_steady_state_warm_replan(social_profiler):
    """A steady-state re-plan (e.g. the violation-trigger path at an
    unchanged demand) must reuse the previous bin's basis — observable via
    the planner's solve-stats counter and the BinReport flag."""
    g, prof = social_profiler
    ctl = Controller(g, prof, s_avail=64,
                     planner_kwargs=dict(max_tuples_per_task=32,
                                         bb_nodes=4, bb_time_s=1.0))
    r0 = ctl.step(0, 100.0, sim_seconds=2.0)
    ctl._planned_for = -1.0     # force a re-plan at the same demand
    r1 = ctl.step(1, 100.0, sim_seconds=2.0)
    assert r0.replanned and r1.replanned
    assert not r0.warm_replan
    assert r1.warm_replan
    assert ctl.planner.stats.warm_basis_hits >= 1


def test_controller_fbar_refinement_feeds_solves(social_profiler):
    """Carried-over ROADMAP item: the single-app controller EWMA-blends
    OBSERVED multiplicative factors (served-traffic ratios) back into
    its planner input, exactly like MultiAppController."""
    g, prof = social_profiler
    ctl = Controller(g, prof, s_avail=64,
                     planner_kwargs=dict(max_tuples_per_task=32,
                                         bb_nodes=4, bb_time_s=1.0))
    rep0 = ctl.step(0, 40.0, sim_seconds=6.0, seed=0)
    assert rep0.violation_rate < 0.05
    # a near-loss-free bin observed F-hat on single-predecessor edges
    assert ctl._fbar, "no observed factors recorded"
    single_pred = {(t, t2) for (t, t2) in g.edges
                   if len(g.predecessors(t2)) == 1}
    assert set(ctl._fbar) <= single_pred
    assert all(0.0 < v < 16.0 for v in ctl._fbar.values())

    # the NEXT solve receives the refined dict (spy on planner.plan)
    seen = {}
    orig = ctl.planner.plan

    def spy(demand, fbar=None, **kw):
        seen["fbar"] = None if fbar is None else dict(fbar)
        return orig(demand, fbar, **kw)

    ctl.planner.plan = spy
    fbar_before = dict(ctl._fbar)
    rep1 = ctl.step(1, 80.0, sim_seconds=6.0, seed=1)  # 2x forces replan
    assert rep1.replanned
    assert seen["fbar"] == fbar_before

    # EWMA update: bin 1's clean run folds new observations in place
    assert ctl._fbar and set(ctl._fbar) <= single_pred

    # and the knob turns it off
    ctl2 = Controller(g, prof, s_avail=64, fbar_refine=False,
                      planner_kwargs=dict(max_tuples_per_task=32,
                                          bb_nodes=4, bb_time_s=1.0))
    ctl2.step(0, 40.0, sim_seconds=6.0, seed=0)
    assert not ctl2._fbar
