"""Accuracy model (Eq. 9-12) properties: the MILP's linearization is a
one-sided lower bound — the central safety invariant (DESIGN.md §5)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import accuracy as acc
from repro.core.apps import get_app
from repro.core.taskgraph import Task, TaskGraph, Variant


def make_graph(acc_a, acc_b):
    t1 = Task("a", (Variant("hi", "gemma-2b", accuracy=acc_a),
                    Variant("lo", "gemma-2b", accuracy=acc_a * 0.9),))
    t2 = Task("b", (Variant("hi", "qwen2-7b", accuracy=acc_b),))
    return TaskGraph("g", {"a": t1, "b": t2}, [("a", "b")])


@settings(max_examples=50, deadline=None)
@given(st.floats(0.5, 1.0), st.floats(0.5, 1.0),
       st.floats(0.0, 1.0))
def test_weierstrass_bound_is_one_sided(aa, ab, mix):
    """a_obj_lower_bound(floors) <= exact a_obj whenever the floors are
    below the exact per-task accuracies."""
    g = make_graph(aa, ab)
    # traffic split between hi/lo variants of task a
    counts = {("a", "hi", "s", 1): 1, ("a", "lo", "s", 1): 1,
              ("b", "hi", "s", 1): 1}
    tput = {("a", "hi", "s", 1): 10.0 * mix + 1e-6,
            ("a", "lo", "s", 1): 10.0 * (1 - mix) + 1e-6,
            ("b", "hi", "s", 1): 5.0}
    exact = acc.a_obj(g, counts, tput)
    floors = {t: acc.effective_task_accuracy(g, t, counts, tput)
              for t in g.tasks}
    lb = acc.a_obj_lower_bound(g, floors)
    assert lb <= exact + 1e-9


def test_a_max_uses_most_accurate_variants():
    g = get_app("traffic_analysis")
    am = acc.a_max(g)
    want = 0.5 * (0.902 * 0.871) + 0.5 * (0.902 * 0.845)
    assert abs(am - want) < 1e-9


def test_a_obj_is_one_with_best_variants():
    g = get_app("social_media")
    counts, tput = {}, {}
    for tname, task in g.tasks.items():
        v = task.most_accurate
        counts[(tname, v.name, "s", 1)] = 1
        tput[(tname, v.name, "s", 1)] = 10.0
    assert abs(acc.a_obj(g, counts, tput) - 1.0) < 1e-9


def test_effective_accuracy_is_throughput_weighted():
    g = make_graph(1.0, 1.0)
    counts = {("a", "hi", "s", 1): 2, ("a", "lo", "s", 1): 1}
    tput = {("a", "hi", "s", 1): 1.0, ("a", "lo", "s", 1): 3.0}
    # weights: hi 2*1=2, lo 1*3=3 → (2*1.0 + 3*0.9)/5
    want = (2 * 1.0 + 3 * 0.9) / 5
    got = acc.effective_task_accuracy(g, "a", counts, tput)
    assert abs(got - want) < 1e-12


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.6, 1.0), min_size=2, max_size=4))
def test_path_product_bound(accs):
    """1 - Σ(1-a) <= Π a for a in [0,1] (Weierstrass)."""
    prod = np.prod(accs)
    bound = 1 - sum(1 - a for a in accs)
    assert bound <= prod + 1e-12
