"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only the dry-run (and explicit subprocess
tests) force 512/8 host devices."""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def null_policy():
    from repro.sharding.policy import ShardingPolicy
    return ShardingPolicy(mesh=None)


@pytest.fixture(scope="session")
def social_profiler():
    from repro.core.apps import get_app
    from repro.core.profiler import Profiler
    g = get_app("social_media")
    return g, Profiler(g)


@pytest.fixture(scope="session")
def traffic_profiler():
    from repro.core.apps import get_app
    from repro.core.profiler import Profiler
    g = get_app("traffic_analysis")
    return g, Profiler(g)
