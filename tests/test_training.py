"""Training substrate: convergence, microbatch equivalence, compression
error-feedback, schedule, data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.models import Model
from repro.sharding.policy import ShardingPolicy
from repro.training import compression as comp
from repro.training import data as data_mod
from repro.training import optimizer as opt
from repro.training.train_step import init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    arch = ARCHS["granite-3-2b"].reduced()
    m = Model(arch, ShardingPolicy(mesh=None), param_dtype=jnp.float32)
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    dcfg = data_mod.for_arch(arch, seq_len=32, global_batch=8)
    return arch, m, cfg, dcfg


def test_loss_decreases(setup):
    arch, m, cfg, dcfg = setup
    state = init_train_state(m, jax.random.key(0), cfg)
    step = jax.jit(make_train_step(m, cfg))
    losses = []
    for i in range(12):
        batch = {k: jnp.asarray(v)
                 for k, v in data_mod.batch_at_step(dcfg, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_microbatch_equivalence(setup):
    """Gradient accumulation over 4 microbatches == single batch step."""
    arch, m, cfg, dcfg = setup
    s1 = init_train_state(m, jax.random.key(0), cfg)
    s4 = init_train_state(m, jax.random.key(0), cfg)
    f1 = jax.jit(make_train_step(m, cfg, microbatches=1))
    f4 = jax.jit(make_train_step(m, cfg, microbatches=4))
    batch = {k: jnp.asarray(v)
             for k, v in data_mod.batch_at_step(dcfg, 0).items()}
    s1, m1 = f1(s1, batch)
    s4, m4 = f4(s4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_compression_error_feedback_residual():
    """quantize → dequantize + error == exact gradient (EF identity)."""
    rng = jax.random.key(0)
    g = jax.random.normal(rng, (64, 64)) * 0.01
    err = jnp.zeros_like(g)
    q, scale, new_err = comp.quantize_grad(g, err)
    recon = comp.dequantize_grad(q, scale) + new_err
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g),
                               rtol=1e-5, atol=1e-7)


def test_compressed_training_tracks_uncompressed(setup):
    """int8 EF compression converges to within noise of exact grads."""
    arch, m, cfg, dcfg = setup
    se = init_train_state(m, jax.random.key(0), cfg)
    sc = init_train_state(m, jax.random.key(0), cfg)
    fe = jax.jit(make_train_step(m, cfg))
    fc = jax.jit(make_train_step(m, cfg, grad_compression="int8"))
    le = lc = None
    for i in range(8):
        batch = {k: jnp.asarray(v)
                 for k, v in data_mod.batch_at_step(dcfg, i).items()}
        se, me = fe(se, batch)
        sc, mc = fc(sc, batch)
        le, lc = float(me["loss"]), float(mc["loss"])
    assert abs(le - lc) < 0.12, (le, lc)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 200))
def test_data_pipeline_deterministic(seed, step):
    cfg = data_mod.DataConfig(vocab_size=100, seq_len=16, global_batch=2,
                              seed=seed)
    b1 = data_mod.batch_at_step(cfg, step)
    b2 = data_mod.batch_at_step(cfg, step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert b1["tokens"].max() < 100
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt.schedule(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= lrs[10] == pytest.approx(1e-3, rel=1e-6)
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))


def test_grad_clip_bounds_update():
    cfg = opt.AdamWConfig(lr=1.0, grad_clip=1e-3, warmup_steps=0,
                          total_steps=10, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init_state(params)
    huge = {"w": jnp.full((4,), 1e6)}
    new_params, _ = opt.apply_updates(cfg, state, huge,
                                      param_dtype=jnp.float32)
    # clipped grad -> bounded first-step delta (|Δ| ≤ lr since |m̂/√v̂|≤1)
    assert np.all(np.abs(np.asarray(new_params["w"]) - 1.0) <= 1.0 + 1e-6)
