"""Use real `hypothesis` when installed, else a deterministic fallback.

The seed image does not ship hypothesis (see requirements-dev.txt), which
used to crash collection of five test modules.  Property tests import
``given``/``settings``/``st`` from here instead: with hypothesis installed
they behave exactly as before; without it, each ``@given`` test runs its
strategies over a fixed deterministic sample of ``max_examples`` draws
(no shrinking, but the same pass/fail semantics on the sampled points).
"""
import functools
import inspect
import random

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(size)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elements))

    st = _strategies

    def settings(max_examples=20, **_kwargs):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # strategies fill the rightmost params (hypothesis semantics);
            # the rest are pytest fixtures, which arrive as kwargs
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            filled = [p.name for p in params[len(params) - len(strats):]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples", 20)
                for i in range(n):
                    rng = random.Random(7919 * i + 13)
                    vals = {name: s.example(rng)
                            for name, s in zip(filled, strats)}
                    fn(*args, **kwargs, **vals)
            # hide the strategy-filled params from pytest so it only
            # injects the remaining ones as fixtures
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - len(strats)])
            del wrapper.__wrapped__
            return wrapper
        return deco
