"""Dry-run machinery integration: lower+compile a REDUCED arch on an
8-device host mesh in a subprocess (the only place tests touch
multi-device state), HLO collective parsing, extrapolation math, and
elastic mesh shapes."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str) -> subprocess.CompletedProcess:
    env = {**os.environ, "PYTHONPATH": SRC,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          env=env, capture_output=True, text=True,
                          timeout=420)


@pytest.mark.slow
def test_reduced_cells_lower_and_compile_on_8_devices():
    """One reduced arch per family × {train, decode} on a 2x4 mesh."""
    r = run_sub("""
        import json
        import jax, jax.numpy as jnp
        import dataclasses
        from repro.configs import get_arch
        from repro.configs.shapes import ShapeConfig
        from repro.launch.dryrun import build_step
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = {}
        for name in ["qwen2-7b", "llama4-scout-17b-a16e", "mamba2-130m",
                     "zamba2-7b"]:
            arch = get_arch(name).reduced()
            for shape in [ShapeConfig("t", 64, 8, "train"),
                          ShapeConfig("d", 64, 8, "decode")]:
                fn, args, policy = build_step(arch, shape, mesh)
                compiled = fn.lower(*args).compile()
                ca = compiled.cost_analysis()
                out[f"{name}/{shape.kind}"] = float(ca.get("flops", 0))
        print("RESULT" + json.dumps(out))
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    payload = [l for l in r.stdout.splitlines() if l.startswith("RESULT")]
    data = json.loads(payload[0][len("RESULT"):])
    assert len(data) == 8
    assert all(v > 0 for v in data.values())


def test_collective_parsing():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
      %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
      %ag.1 = bf16[64]{0} all-gather(bf16[32]{0} %y), dimensions={0}
      %rs = f32[16,16]{1,0} reduce-scatter(f32[32,16]{1,0} %z), dimensions={0}
      %cp = u32[8]{0} collective-permute(u32[8]{0} %w), source_target_pairs={}
      %notacoll = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
    """
    got = parse_collective_bytes(hlo)
    # physical ring-traffic accounting: AR at 2x operand (reduce-scatter +
    # all-gather phases), AG at result size, RS/permute at operand size
    assert got["all-reduce"] == 2 * 128 * 256 * 4
    assert got["all-gather"] == 64 * 2          # result bf16[64]
    assert got["reduce-scatter"] == 32 * 16 * 4
    assert got["collective-permute"] == 8 * 4
    assert "add" not in got


def test_extrapolation_math_linear():
    """f(L) linear in L ⇒ est == exact."""
    from repro.launch.dryrun import depth_pair
    from repro.configs import get_arch
    a = get_arch("qwen2-7b")
    L1, L2 = depth_pair(a)
    assert (L1, L2) == (1, 2)
    assert depth_pair(get_arch("llama4-maverick-400b-a17b")) == (2, 4)
    assert depth_pair(get_arch("zamba2-7b")) == (6, 12)
    f = lambda L: 3.0 + 2.0 * L     # affine cost model
    per = (f(L2) - f(L1)) / (L2 - L1)
    est = f(L1) + per * (a.num_layers - L1)
    assert est == pytest.approx(f(a.num_layers))


def test_input_specs_cover_all_cells():
    import jax.numpy as jnp
    from repro.configs import ARCHS, SHAPES, applicable
    from repro.launch.specs import input_specs
    for a in ARCHS.values():
        for s in SHAPES.values():
            if not applicable(a, s):
                continue
            spec = input_specs(a, s)
            assert "tokens" in spec
            if s.kind == "train":
                assert spec["labels"].shape == (s.global_batch, s.seq_len)
            if s.kind == "decode":
                assert spec["tokens"].shape == (s.global_batch, 1)
                assert "cache" in spec
            if a.frontend != "none" and s.kind in ("train", "prefill"):
                assert "frontend_embeds" in spec


def test_elastic_mesh_shapes():
    from repro.training.elastic import reshard_plan, viable_mesh_shape
    shape, names = viable_mesh_shape(512, 16, prefer_pods=2)
    assert shape == (2, 16, 16) and names == ("pod", "data", "model")
    shape, names = viable_mesh_shape(496, 16)     # lost a host
    assert shape == (31, 16)
    with pytest.raises(ValueError):
        viable_mesh_shape(8, 16)


def test_production_mesh_shapes_via_subprocess():
    r = run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("MESHOK")
    """)
    assert r.returncode == 0 and "MESHOK" in r.stdout, r.stderr[-2000:]
