"""Live reconfiguration engine (DESIGN.md §12): plan-diff transitions,
MIG repartition / weight-load delays, staged runtime execution and the
switching-cost-aware (sticky) MILP objective."""
import pytest

from repro.core.apps import get_app
from repro.core.controller import Controller, MultiAppController
from repro.core.milp import PlanConfig, Planner, TupleVar
from repro.core.profiler import Profiler
from repro.core.taskgraph import Task, TaskGraph, Variant
from repro.hwspec import tight_hetero_cluster
from repro.reconfig import TransitionAction, TransitionPlan, \
    TransitionPlanner
from repro.runtime import (ClusterRuntime, EngineBackend, Scenario,
                           SimBackend, TransitionEvent)

KW = dict(max_tuples_per_task=32, bb_nodes=8, bb_time_s=3.0)


@pytest.fixture(scope="module")
def cluster():
    return tight_hetero_cluster()


@pytest.fixture(scope="module")
def social(cluster):
    g = get_app("social_media")
    return g, Profiler(g, cluster=cluster)


@pytest.fixture(scope="module")
def lo_hi(cluster, social):
    """Two plans far enough apart in demand that the deployment changes."""
    g, prof = social
    pl = Planner(g, prof, s_avail=cluster.total_units, **KW)
    cfg_lo = pl.plan(10.0)
    cfg_hi = pl.plan(90.0)
    assert cfg_lo is not None and cfg_hi is not None
    assert cfg_lo.counts != cfg_hi.counts
    return cfg_lo, cfg_hi


def _one_task_graph():
    return TaskGraph(
        name="single", tasks={"t": Task("t", (
            Variant("v", "gemma-2b", accuracy=0.9),))},
        edges=[], slo_latency_ms=20_000.0, slo_accuracy=0.5)


def _cfg(graph, segment, batch, count, pool, latency_ms=200.0,
         throughput=20.0, cost=1):
    tup = TupleVar("t", "v", segment, batch, latency_ms, throughput,
                   cost, 0.9, pool, streams=1)
    return PlanConfig(graph, {tup.key: count}, {tup.key: tup},
                      {"t": count * throughput})


# ---------------------------------------------------------------------------
# TransitionPlanner diffs
# ---------------------------------------------------------------------------
def test_zero_diff_is_empty(cluster, social, lo_hi):
    g, _ = social
    _, cfg_hi = lo_hi
    tr = TransitionPlanner(cluster, g).plan(cfg_hi, cfg_hi)
    assert tr.is_empty
    assert tr.makespan_s == 0.0
    assert not tr.repartition_pools
    # every deployed instance is a keep
    assert sum(a.count for a in tr.keeps) == \
        sum(m for m in cfg_hi.counts.values() if m > 0)


def test_cold_start_has_no_actions(cluster, social, lo_hi):
    g, _ = social
    tr = TransitionPlanner(cluster, g).plan(None, lo_hi[1])
    assert tr.is_empty and tr.makespan_s == 0.0


def test_staged_diff_structure_and_delays(cluster, social, lo_hi):
    g, _ = social
    cfg_lo, cfg_hi = lo_hi
    tp = TransitionPlanner(cluster, g)
    tr = tp.plan(cfg_lo, cfg_hi)
    assert not tr.is_empty
    assert tr.makespan_s > 0.0
    # keep + load reproduces the target exactly
    got = {}
    for a in tr.keeps + tr.loads:
        got[a.tup.key] = got.get(a.tup.key, 0) + a.count
    assert got == {k: m for k, m in cfg_hi.counts.items() if m > 0}
    # every load waits for its weights; drains cover until hand-over
    for a in tr.loads:
        assert a.ready_s >= tp.weight_load_s("", a.tup) - 1e-9
    for a in tr.drains:
        same_task = [x.ready_s for x in tr.loads
                     if x.tup.task == a.tup.task]
        if same_task and not tr.blocked_pools:
            assert a.retire_s == pytest.approx(max(same_task))
    # delay_scale=0: same structure, instantaneous
    tr0 = TransitionPlanner(cluster, g, delay_scale=0.0).plan(cfg_lo,
                                                             cfg_hi)
    assert tr0.makespan_s == 0.0
    assert sum(a.count for a in tr0.loads) == \
        sum(a.count for a in tr.loads)


def test_idle_drains_swept_from_fleet(cluster):
    """A blocked drain that never receives work must not linger as fake
    capacity: the retire sweep removes it, so the lost-all-instances
    guard sees the true fleet."""
    g1 = _one_task_graph()
    old = _cfg(g1, "1x1s1", 4, 1, "v5e")
    new = _cfg(g1, "1x1s2", 4, 1, "v5e")
    key_old = next(iter(old.tuples))
    tr = TransitionPlan(
        keeps=(),
        drains=(TransitionAction("drain", "", old.tuples[key_old], 1,
                                 retire_s=0.0),),
        loads=(TransitionAction("load", "", new.tuples[
            next(iter(new.tuples))], 1, ready_s=1.0),),
        target={"": new}, makespan_s=1.0,
        repartition_pools=frozenset(), blocked_pools=frozenset())
    rt = ClusterRuntime(g1, new, SimBackend(), seed=0, transition=tr)
    rt.run(Scenario.poisson(5.0, duration_s=4.0, warmup_s=0.0))
    assert not any(s.tup.key == key_old for s in rt.servers)


def test_mig_repartition_blocks_torus_does_not(cluster):
    g1 = _one_task_graph()
    tp = TransitionPlanner(cluster, g1)
    # MIG: 2g -> 3g needs carving a new slice; the device pauses
    old = _cfg(g1, "2g.10gb.s1", 4, 1, "mig", cost=2)
    new = _cfg(g1, "3g.20gb.s1", 4, 1, "mig", cost=3)
    tr = tp.plan(old, new)
    assert tr.repartition_pools == frozenset({"mig"})
    assert tr.blocked_pools == frozenset({"mig"})
    (load,) = tr.loads
    (drain,) = tr.drains
    assert load.carved
    mig = cluster.pool("mig")
    assert load.ready_s >= mig.scheme.repartition_delay_s
    assert drain.retire_s == 0.0          # in-flight only: slice blocked
    # torus: 1 chip -> 2 chips is a host-side regroup; old keeps serving
    old_t = _cfg(g1, "1x1s1", 4, 1, "v5e")
    new_t = _cfg(g1, "1x2s1", 4, 1, "v5e", cost=2)
    tr_t = tp.plan(old_t, new_t)
    assert tr_t.repartition_pools == frozenset({"v5e"})
    assert not tr_t.blocked_pools
    (drain_t,) = tr_t.drains
    (load_t,) = tr_t.loads
    assert drain_t.retire_s == pytest.approx(load_t.ready_s)
    assert drain_t.retire_s > 0.0


def test_same_physical_slice_reused_without_carving(cluster):
    g1 = _one_task_graph()
    tp = TransitionPlanner(cluster, g1)
    # 2g.10gb.s1 -> 2g.10gb.s2: streams are software, same physical slice
    old = _cfg(g1, "2g.10gb.s1", 4, 1, "mig", cost=2)
    new = _cfg(g1, "2g.10gb.s2", 4, 1, "mig", cost=2)
    tr = tp.plan(old, new)
    assert not tr.repartition_pools
    (load,) = tr.loads
    assert not load.carved
    assert load.ready_s == pytest.approx(tp.weight_load_s("", load.tup))


def test_removed_app_is_fully_drained(cluster):
    """An app present in the incumbent but dropped from the target must
    drain its whole fleet — no zombie servers."""
    from repro.core.milp import JointPlan
    g1 = _one_task_graph()
    cfg_a = _cfg(g1, "1x1s1", 4, 1, "v5e")
    cfg_b = _cfg(g1, "1x1s2", 4, 2, "v5e")
    tp = TransitionPlanner(cluster, {"a": g1, "b": g1})
    old = JointPlan({"a": cfg_a, "b": cfg_b}, {"v5e": 8}, {})
    new = JointPlan({"a": cfg_a}, {"v5e": 8}, {})
    tr = tp.plan_joint(old, new)
    assert sum(a.count for a in tr.drains if a.app == "b") == 2
    assert not any(a.app == "b" for a in tr.loads)
    assert "b" not in tr.target


def test_atomic_policy_swaps_everything(cluster, social, lo_hi):
    g, _ = social
    cfg_lo, cfg_hi = lo_hi
    tr = TransitionPlanner(cluster, g, policy="atomic").plan(cfg_lo,
                                                            cfg_hi)
    assert not tr.keeps
    assert sum(a.count for a in tr.drains) == \
        sum(m for m in cfg_lo.counts.values() if m > 0)
    assert all(a.retire_s == 0.0 for a in tr.drains)
    # nothing serves before the global makespan
    assert all(a.ready_s == pytest.approx(tr.makespan_s)
               for a in tr.loads)


# ---------------------------------------------------------------------------
# runtime execution
# ---------------------------------------------------------------------------
def test_drain_preserves_inflight_requests(cluster):
    """Work dispatched to a draining instance before its hand-over point
    completes even when service runs past it — and is served long before
    the replacement warms up."""
    g1 = _one_task_graph()
    old = _cfg(g1, "1x1s1", 4, 1, "v5e")
    new = _cfg(g1, "1x1s2", 4, 1, "v5e")
    key_old = next(iter(old.tuples))
    key_new = next(iter(new.tuples))
    tr = TransitionPlan(
        keeps=(),
        drains=(TransitionAction("drain", "", old.tuples[key_old], 1,
                                 retire_s=0.5),),
        loads=(TransitionAction("load", "", new.tuples[key_new], 1,
                                ready_s=5.0),),
        target={"": new}, makespan_s=5.0,
        repartition_pools=frozenset(), blocked_pools=frozenset())
    rt = ClusterRuntime(g1, new, SimBackend(), seed=3, transition=tr)
    m = rt.run(Scenario.poisson(10.0, duration_s=8.0, warmup_s=0.0))
    assert m.completions > 0
    assert m.window is not None
    assert m.window.completions > 0
    # the drain served the early arrivals: sub-second latencies exist,
    # far below the 5 s the loading replacement would impose
    assert min(m.latencies_ms) < 1000.0


def test_staged_beats_atomic_in_transition_window(cluster, social, lo_hi):
    g, _ = social
    cfg_lo, cfg_hi = lo_hi
    staged = TransitionPlanner(cluster, g).plan(cfg_lo, cfg_hi)
    atomic = TransitionPlanner(cluster, g, policy="atomic").plan(cfg_lo,
                                                                cfg_hi)
    sc = Scenario.poisson(90.0, duration_s=10.0, warmup_s=0.0)
    out = {}
    for name, tr in (("staged", staged), ("atomic", atomic)):
        rt = ClusterRuntime(g, cfg_hi, SimBackend(), seed=0,
                            transition=tr)
        m = rt.run(sc)
        assert m.window is not None
        assert m.transition_window_s == pytest.approx(tr.makespan_s)
        out[name] = m
    assert out["staged"].window.violations < \
        out["atomic"].window.violations
    assert out["staged"].violations < out["atomic"].violations


def test_scheduled_transition_event_mid_run(cluster, social, lo_hi):
    """A TransitionEvent reconfigures a RUNNING fleet: the old plan
    serves until the event, then drains while the new plan warms up."""
    g, _ = social
    cfg_lo, cfg_hi = lo_hi
    tr = TransitionPlanner(cluster, g).plan(cfg_lo, cfg_hi)
    rt = ClusterRuntime(g, cfg_lo, SimBackend(), seed=1)
    sc = Scenario.step_change(10.0, 90.0, duration_s=12.0, warmup_s=0.0,
                              switch_frac=0.5).with_transitions(
        TransitionEvent(at_s=6.0, plan=tr))
    m = rt.run(sc)
    assert m.window is not None
    assert m.transition_window_s == pytest.approx(tr.makespan_s)
    # the runtime now runs the TARGET config
    assert rt.config is cfg_hi
    new_keys = {k for k, mm in cfg_hi.counts.items() if mm > 0}
    assert {s.tup.key for s in rt.servers} >= new_keys
    assert m.completions > 0


def test_transition_for_wrong_target_fails_loud(cluster, social, lo_hi):
    g, _ = social
    cfg_lo, cfg_hi = lo_hi
    tr = TransitionPlanner(cluster, g).plan(cfg_lo, cfg_hi)
    with pytest.raises(ValueError, match="transition"):
        ClusterRuntime(g, cfg_lo, SimBackend(), transition=tr)


# ---------------------------------------------------------------------------
# switching-cost-aware planning
# ---------------------------------------------------------------------------
def test_stickiness_zero_is_bit_identical(cluster, social):
    g, prof = social
    a = Planner(g, prof, s_avail=cluster.total_units, **KW).plan(40.0)
    p = Planner(g, prof, s_avail=cluster.total_units, **KW)
    inc = p.plan(10.0)
    b = p.plan(40.0, incumbent=inc)     # stickiness defaults to 0
    assert a.counts == b.counts
    assert a.exact_a_obj() == b.exact_a_obj()
    assert a.slices == b.slices


def test_stickiness_prefers_incumbent_tuple_types(cluster, social):
    g, prof = social

    def changed(cfg, inc):
        ik = {k for k, m in inc.counts.items() if m > 0}
        return len({k for k, m in cfg.counts.items() if m > 0} - ik)

    ps = Planner(g, prof, s_avail=cluster.total_units, stickiness=2.0,
                 **KW)
    inc = ps.plan(10.0)
    plain = Planner(g, prof, s_avail=cluster.total_units,
                    **KW).plan(90.0)
    sticky = ps.plan(90.0, incumbent=inc)
    assert sticky is not None
    assert sticky.feasible(g.slo_latency_ms, g.slo_accuracy,
                           cluster.total_units)
    assert changed(sticky, inc) <= changed(plain, inc)
    assert changed(sticky, inc) < sum(
        1 for m in sticky.counts.values() if m > 0)


# ---------------------------------------------------------------------------
# controller integration + satellites
# ---------------------------------------------------------------------------
def test_controller_executes_staged_transitions(cluster, social):
    g, prof = social
    ctl = Controller(g, prof, s_avail=cluster.total_units,
                     planner_kwargs=dict(KW, stickiness=0.25),
                     reconfig=TransitionPlanner(cluster, g))
    r0 = ctl.step(0, 10.0, sim_seconds=6.0, seed=0)
    assert r0.transition_s == 0.0        # cold start: no incumbent
    r1 = ctl.step(1, 90.0, sim_seconds=6.0, seed=1)
    assert r1.replanned
    assert r1.transition_s > 0.0
    assert r1.transition_actions > 0
    # steady bin: no plan change, no transition charged
    r2 = ctl.step(2, 90.0, sim_seconds=6.0, seed=2)
    assert r2.transition_s == 0.0 or r2.transition_actions >= 0


def test_controller_pool_aware_dead_units(cluster, social):
    g, prof = social
    ctl = Controller(g, prof, s_avail=cluster.total_units,
                     planner_kwargs=dict(KW))
    mig_units = cluster.pool("mig").capacity_units
    rep = ctl.step(0, 40.0, sim_seconds=4.0,
                   dead_units={"mig": mig_units})
    assert rep.violation_rate < 0.2
    # the whole MIG pool is dead: nothing may be planned there
    assert "mig" not in ctl._config.pool_slices()
    assert ctl.planner.pool_budgets()["mig"] == 0


def test_planner_dead_units_budgets(cluster, social):
    g, prof = social
    p = Planner(g, prof, s_avail=cluster.total_units, **KW)
    base = p.pool_budgets()
    p.dead_units = {"v5e": 3}
    got = p.pool_budgets()
    assert got["v5e"] == base["v5e"] - 3
    assert got["mig"] == base["mig"]
    # direct API on the implicit single-pool cluster: dead units shrink
    # the ONE pool's budget without any caller-side s_avail adjustment
    gd, profd = g, Profiler(g)
    pd = Planner(gd, profd, s_avail=64, **KW)
    pd.dead_units = {"v5e": 4}
    assert pd.pool_budgets() == {"v5e": 60}
    # a typo'd pool name must fail loud, not model the failure as zero
    pd.dead_units = {"v5e-typo": 4}
    with pytest.raises(ValueError, match="unknown pools"):
        pd.pool_budgets()


def test_dead_capacity_not_used_for_warmups(cluster):
    """Spare warm-up headroom excludes dead units: with the pool's free
    capacity dead, the staged plan must not warm new instances 'next
    to' the old fleet — it reclaims the drained region instead."""
    g1 = _one_task_graph()
    old = _cfg(g1, "1x1s1", 4, 1, "v5e")
    new = _cfg(g1, "1x2s1", 4, 1, "v5e", cost=2)
    tp = TransitionPlanner(cluster, g1)
    free = cluster.pool("v5e").capacity_units - 1   # all-but-used dead
    tr = tp.plan(old, new, dead_units={"v5e": free})
    (drain,) = tr.drains
    assert drain.retire_s == 0.0       # region reclaimed for the carve
    with_spare = tp.plan(old, new)
    assert with_spare.drains[0].retire_s > 0.0


def test_staged_capacity_honest(cluster, social, lo_hi):
    """Per pool, concurrently dispatchable capacity (keeps + drains
    still serving + loads warming on spare) never exceeds the pool's
    physical units at any point of the transition."""
    g, _ = social
    cfg_lo, cfg_hi = lo_hi
    tr = TransitionPlanner(cluster, g).plan(cfg_lo, cfg_hi)

    def usage_at(t):
        use = {}
        for a in tr.keeps:
            p = a.tup.pool
            use[p] = use.get(p, 0) + a.tup.cost * a.count
        for a in tr.drains:
            if a.retire_s > t:
                use[a.tup.pool] = use.get(a.tup.pool, 0) \
                    + a.tup.cost * a.count
        for a in tr.loads:
            # a load occupies its slice from the moment staging starts
            use[a.tup.pool] = use.get(a.tup.pool, 0) \
                + a.tup.cost * a.count
        return use

    for t in (0.0, tr.makespan_s / 2, tr.makespan_s):
        for p, u in usage_at(t).items():
            assert u <= cluster.pool(p).capacity_units, (t, p, u)


def test_engine_backend_per_pool_time_scale():
    eb = EngineBackend(time_scale=2.0, pool_time_scale={"mig": 0.5})
    assert eb.scale_for("mig") == 0.5
    assert eb.scale_for("v5e") == 2.0
    assert EngineBackend().scale_for("anything") == 1.0


def test_multiapp_fbar_refinement(cluster):
    graphs = {n: get_app(n) for n in ("social_media",
                                      "traffic_analysis")}
    profs = {n: Profiler(g, cluster=cluster)
             for n, g in graphs.items()}
    ctl = MultiAppController(graphs, profs,
                             s_avail=cluster.total_units,
                             planner_kwargs=dict(KW))
    ctl.step(0, {"social_media": 30.0, "traffic_analysis": 10.0},
             sim_seconds=6.0, seed=0)
    # observed factors were fed back per app (single-predecessor edges)
    fb = ctl._fbar["traffic_analysis"]
    assert fb, "no observed factors recorded"
    assert all(v > 0.0 for v in fb.values())
    g = graphs["traffic_analysis"]
    assert all(len(g.predecessors(t2)) == 1 for (_t, t2) in fb)
