"""Discrete-event simulator: conservation, SLO behaviour at planned
demand, overload degradation, straggler & failure handling."""
import numpy as np
import pytest

from repro.core.milp import Planner
from repro.core.simulator import Simulator


@pytest.fixture(scope="module")
def planned(traffic_profiler):
    g, prof = traffic_profiler
    planner = Planner(g, prof, s_avail=128, max_tuples_per_task=32,
                      bb_nodes=4, bb_time_s=1.0)
    cfg = planner.plan(60.0)
    assert cfg is not None
    return g, cfg


def test_low_violations_at_planned_demand(planned):
    g, cfg = planned
    m = Simulator(g, cfg, seed=0).run(60.0, duration_s=15.0, warmup_s=3.0)
    assert m.completions > 100
    assert m.violation_rate < 0.02, m.violation_rate


def test_overload_raises_violations(planned):
    g, cfg = planned
    m_ok = Simulator(g, cfg, seed=1).run(60.0, duration_s=12.0, warmup_s=3.0)
    m_over = Simulator(g, cfg, seed=1).run(600.0, duration_s=12.0,
                                           warmup_s=3.0)
    assert m_over.violation_rate > m_ok.violation_rate
    assert m_over.violation_rate > 0.2


def test_accuracy_accounting_within_variant_range(planned):
    g, cfg = planned
    m = Simulator(g, cfg, seed=2).run(60.0, duration_s=10.0, warmup_s=2.0)
    a = m.realized_a_obj(g)
    assert 0.0 < a <= 1.0 + 1e-9
    for t in g.tasks:
        ta = m.realized_task_accuracy(g, t)
        accs = [v.accuracy for v in g.tasks[t].variants]
        assert min(accs) - 1e-9 <= ta <= max(accs) + 1e-9


def test_latencies_within_slo_envelope(planned):
    g, cfg = planned
    m = Simulator(g, cfg, seed=3).run(60.0, duration_s=12.0, warmup_s=3.0)
    assert m.latencies_ms, "no completions recorded"
    # violations are already counted; surviving p99 must be sane
    assert m.p99_ms < g.slo_latency_ms * 1.5


def test_straggler_tail_absorbed(planned):
    """4x the latency jitter should not collapse the SLO at planned load
    (early-drop + shared queue handles stragglers)."""
    g, cfg = planned
    m = Simulator(g, cfg, seed=4, jitter_sigma=0.32).run(
        60.0, duration_s=12.0, warmup_s=3.0)
    assert m.violation_rate < 0.10


def test_instance_failure_absorbed_or_flagged(planned):
    g, cfg = planned
    sim = Simulator(g, cfg, seed=5)
    # kill one server of the task with the most servers
    task = max(sim.by_task, key=lambda t: len(sim.by_task[t]))
    victim = sim.by_task[task][0].idx
    if len(sim.by_task[task]) > 1:
        sim.fail_instances([victim])
        m = sim.run(30.0, duration_s=10.0, warmup_s=2.0)
        assert m.completions > 0
    else:
        with pytest.raises(RuntimeError, match="re-plan"):
            sim.fail_instances([victim])


def test_total_task_loss_raises(planned):
    g, cfg = planned
    sim = Simulator(g, cfg, seed=6)
    task = next(iter(sim.by_task))
    with pytest.raises(RuntimeError):
        sim.fail_instances([s.idx for s in sim.by_task[task]])


def test_determinism_per_seed(planned):
    g, cfg = planned
    m1 = Simulator(g, cfg, seed=7).run(40.0, duration_s=8.0, warmup_s=2.0)
    m2 = Simulator(g, cfg, seed=7).run(40.0, duration_s=8.0, warmup_s=2.0)
    assert m1.completions == m2.completions
    assert m1.violations == m2.violations
