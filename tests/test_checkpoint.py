"""Checkpointing: roundtrip, atomicity under interrupted writes, restart
determinism, pruning."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2, 2), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    t = tree()
    ckpt.save(d, 5, t)
    restored, step = ckpt.restore(d, jax.eval_shape(lambda: t))
    assert step == 5
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_latest_pointer_tracks_newest(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, tree())
    ckpt.save(d, 9, tree())
    assert ckpt.latest_step(d) == 9


def test_interrupted_save_never_corrupts(tmp_path):
    """A crash mid-write leaves a .tmp dir; LATEST still points at the
    good checkpoint and restore succeeds."""
    d = str(tmp_path)
    t = tree()
    ckpt.save(d, 3, t)
    # simulate a writer dying mid-save for step 4
    broken = os.path.join(d, "step_00000004.tmp")
    os.makedirs(broken)
    with open(os.path.join(broken, "leaf_00000.npy"), "wb") as f:
        f.write(b"garbage")
    restored, step = ckpt.restore(d, jax.eval_shape(lambda: t))
    assert step == 3


def test_structure_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, tree())
    wrong = {"only": jnp.zeros((2,))}
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(d, jax.eval_shape(lambda: wrong))


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, tree())
    t2 = tree()
    t2["a"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(d, jax.eval_shape(lambda: t2))


def test_prune_keeps_newest(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, tree())
    ckpt.prune(d, keep=2)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_"))
    assert steps == [4, 5]
    restored, step = ckpt.restore(d, jax.eval_shape(lambda: tree()))
    assert step == 5


def test_training_restart_is_bit_deterministic(tmp_path):
    """Train 6 steps straight vs 3 + restore + 3: identical loss."""
    from repro.configs import ARCHS
    from repro.models import Model
    from repro.sharding.policy import ShardingPolicy
    from repro.training import data as data_mod
    from repro.training import optimizer as opt
    from repro.training.train_step import init_train_state, make_train_step

    arch = ARCHS["gemma-2b"].reduced()
    m = Model(arch, ShardingPolicy(mesh=None), param_dtype=jnp.float32)
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    step_fn = jax.jit(make_train_step(m, cfg))
    dcfg = data_mod.for_arch(arch, seq_len=32, global_batch=4)

    def run(state, lo, hi):
        out = None
        for i in range(lo, hi):
            batch = {k: jnp.asarray(v)
                     for k, v in data_mod.batch_at_step(dcfg, i).items()}
            state, out = step_fn(state, batch)
        return state, out

    s0 = init_train_state(m, jax.random.key(0), cfg)
    s_direct, m_direct = run(s0, 0, 6)

    s1 = init_train_state(m, jax.random.key(0), cfg)
    s1, _ = run(s1, 0, 3)
    ckpt.save(str(tmp_path), 3, s1)
    s2, _ = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: s1))
    s2, m_resumed = run(s2, 3, 6)
    assert float(m_direct["loss"]) == pytest.approx(
        float(m_resumed["loss"]), abs=1e-6)
