"""Per-arch REDUCED smoke tests (assignment requirement): one forward +
one train step on CPU, asserting output shapes and no NaNs; plus decode-
vs-full-forward cache consistency for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import LOSS_IGNORE, Model
from repro.sharding.policy import ShardingPolicy
from repro.training import optimizer as opt
from repro.training.train_step import init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _model(name, dtype=jnp.float32):
    arch = ARCHS[name].reduced()
    if arch.moe is not None:  # avoid capacity drops in consistency checks
        arch = dataclasses.replace(
            arch, moe=dataclasses.replace(
                arch.moe, capacity_factor=float(arch.moe.num_experts)))
    return arch, Model(arch, ShardingPolicy(mesh=None), param_dtype=dtype)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_no_nans(name):
    arch, m = _model(name)
    params = m.init(jax.random.key(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                arch.vocab_size)
    fe = (jnp.zeros((B, 8, arch.d_model)) if arch.frontend != "none"
          else None)
    logits = m.forward(params, tokens, fe)
    assert logits.shape == (B, S, arch.vocab_size)
    assert logits.dtype == jnp.float32
    assert not np.any(np.isnan(np.asarray(logits)))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step(name):
    arch, m = _model(name)
    cfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(m, jax.random.key(0), cfg)
    step = jax.jit(make_train_step(m, cfg))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0,
                                arch.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(LOSS_IGNORE)
    batch = {"tokens": tokens, "labels": labels}
    if arch.frontend != "none":
        batch["frontend_embeds"] = jnp.zeros((B, 8, arch.d_model))
        batch["labels"] = batch["labels"].at[:, :8].set(LOSS_IGNORE)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["opt"]["step"]) == 1
    # params actually changed
    flat0 = jax.tree.leaves(state["params"])
    assert all(np.all(np.isfinite(np.asarray(x))) for x in flat0)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_matches_full_forward(name):
    arch, m = _model(name)
    params = m.init(jax.random.key(2))
    B, S, extra = 2, 24, 3
    tokens = jax.random.randint(jax.random.key(3), (B, S + extra), 0,
                                arch.vocab_size)
    fe = (jnp.zeros((B, 8, arch.d_model)) if arch.frontend != "none"
          else None)
    full = m.forward(params, tokens, fe)
    _, cache = m.prefill(params, tokens[:, :S], fe, max_seq=S + extra)
    for i in range(extra):
        dl, cache = m.decode_step(params, cache, jnp.int32(S + i),
                                  tokens[:, S + i:S + i + 1])
        ref = np.asarray(full[:, S + i])
        got = np.asarray(dl[:, 0])
        err = np.max(np.abs(ref - got)) / (np.max(np.abs(ref)) + 1e-9)
        assert err < 1e-4, (name, i, err)


def test_remat_matches_no_remat():
    arch = ARCHS["granite-3-2b"].reduced()
    pol = ShardingPolicy(mesh=None)
    m1 = Model(arch, pol, param_dtype=jnp.float32, remat="none")
    m2 = Model(arch, pol, param_dtype=jnp.float32, remat="dots")
    params = m1.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                arch.vocab_size)
    labels = jnp.roll(tokens, -1, 1)
    l1 = m1.loss(params, {"tokens": tokens, "labels": labels})
    l2 = m2.loss(params, {"tokens": tokens, "labels": labels})
    assert abs(float(l1) - float(l2)) < 1e-5
    g1 = jax.grad(lambda p: m1.loss(p, {"tokens": tokens, "labels": labels}))(params)
    g2 = jax.grad(lambda p: m2.loss(p, {"tokens": tokens, "labels": labels}))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_tokens():
    """GShard capacity semantics: tight capacity must change outputs."""
    arch = ARCHS["llama4-scout-17b-a16e"].reduced()
    tight = dataclasses.replace(
        arch, moe=dataclasses.replace(arch.moe, capacity_factor=0.25))
    loose = dataclasses.replace(
        arch, moe=dataclasses.replace(arch.moe, capacity_factor=16.0))
    pol = ShardingPolicy(mesh=None)
    mt = Model(tight, pol, param_dtype=jnp.float32)
    ml = Model(loose, pol, param_dtype=jnp.float32)
    params = mt.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0,
                                arch.vocab_size)
    lt = mt.forward(params, tokens)
    ll = ml.forward(params, tokens)
    assert not np.allclose(np.asarray(lt), np.asarray(ll))


def test_vlm_frontend_replaces_prefix():
    arch = ARCHS["pixtral-12b"].reduced()
    m = Model(arch, ShardingPolicy(mesh=None), param_dtype=jnp.float32)
    params = m.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, 16), 0,
                                arch.vocab_size)
    fe1 = jnp.zeros((1, 8, arch.d_model))
    fe2 = jnp.ones((1, 8, arch.d_model)) * 0.1
    l1 = m.forward(params, tokens, fe1)
    l2 = m.forward(params, tokens, fe2)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
    # suffix token change does not affect causal prefix logits
    t2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % arch.vocab_size)
    l3 = m.forward(params, t2, fe1)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l3[:, :-1]), rtol=1e-5)
