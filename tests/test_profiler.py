"""Closed-form roofline profiler: monotonicity + physical sanity (the
properties the MILP's choices rely on)."""
import pytest

from repro.core import hw
from repro.core.apps import get_app
from repro.core.profiler import BATCH_SIZES, Profiler
from repro.sharding.segments import SegmentType, catalogue


@pytest.fixture(scope="module")
def prof(traffic_profiler):
    return traffic_profiler[1]


def test_latency_monotone_in_batch(prof):
    for (t, v, s, b), e in prof.table.items():
        if b == 1:
            for b2 in BATCH_SIZES[1:]:
                e2 = prof.get(t, v, s, b2)
                if e2 is not None:
                    assert e2.latency_ms >= e.latency_ms * 0.99, \
                        (t, v, s, b2)


def test_throughput_nondecreasing_in_batch(prof):
    """Bigger batches never reduce instance throughput (amortized reads)."""
    keys = sorted(prof.table)
    for (t, v, s, b) in keys:
        nxt = prof.get(t, v, s, b * 2)
        cur = prof.get(t, v, s, b)
        if nxt is not None and cur is not None:
            assert nxt.throughput_rps >= cur.throughput_rps * 0.95


def test_more_chips_reduce_latency(prof):
    """Same variant/batch/streams on a bigger segment is never slower."""
    for (t, v, s, b), e in prof.table.items():
        if e.streams != 1 or b != 1:
            continue
        for seg in catalogue():
            if seg.streams == 1 and seg.chips > e.chips:
                e2 = prof.get(t, v, seg.name, b)
                if e2 is not None:
                    assert e2.latency_ms <= e.latency_ms * 1.01


def test_streams_trade_latency_for_throughput(prof):
    for (t, v, s, b), e in prof.table.items():
        if e.streams != 1:
            continue
        seg4 = s.replace("s1", "s4")
        e4 = prof.get(t, v, seg4, b)
        if e4 is None:
            continue
        assert e4.throughput_rps >= e.throughput_rps * 0.99
        assert e4.latency_ms >= e.latency_ms * 0.99


def test_memory_bound_models_benefit_from_streams(prof):
    """The MPS-analogue property: a memory-bound (u<0.25) single-stream
    entry gains >2x throughput from 4 streams."""
    found = 0
    for (t, v, s, b), e in prof.table.items():
        if e.streams == 1 and e.utilization < 0.25:
            e4 = prof.get(t, v, s.replace("s1", "s4"), b)
            if e4 is not None:
                assert e4.throughput_rps > 2.0 * e.throughput_rps * 0.99
                found += 1
    assert found > 0, "no memory-bound entries to check"


def test_oom_configs_excluded():
    """pixtral-12b (24.6 GB bf16) cannot fit one chip's 14.4 usable GiB;
    a 1x2 segment (two chips) holds it."""
    g = get_app("ar_assistant")
    prof = Profiler(g)
    assert prof.get("detect", "pixtral-12b", "1x1s1", 1) is None
    assert prof.get("detect", "pixtral-12b", "1x2s1", 1) is not None


def test_int8_variant_dominates_bf16_on_speed(prof):
    """Same arch quantized: lower latency, higher throughput (2x MXU +
    halved weight traffic)."""
    pairs = 0
    for (t, v, s, b), e in prof.table.items():
        if not v.endswith("-int8"):
            continue
        base = prof.get(t, v[:-5], s, b)
        if base is not None:
            assert e.latency_ms <= base.latency_ms * 1.01
            assert e.throughput_rps >= base.throughput_rps * 0.99
            pairs += 1
    assert pairs > 0


def test_observe_refines_latency(prof):
    key = next(iter(prof.table))
    import copy
    p2 = Profiler(prof.graph, table=dict(prof.table))
    before = p2.table[key].latency_ms
    p2.observe(key, measured_latency_ms=before * 2.0)
    after = p2.table[key].latency_ms
    assert before < after < before * 2.0


def test_hbm_feasibility_respected(prof):
    for e in prof.table.values():
        assert e.hbm_per_chip <= hw.HBM_BYTES * hw.HBM_USABLE_FRACTION
