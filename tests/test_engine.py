"""In-process serving engine + batcher on a reduced model (CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import Model
from repro.serving.batcher import Batcher, ServeRequest
from repro.serving.engine import Engine, EngineConfig
from repro.sharding.policy import ShardingPolicy


@pytest.fixture(scope="module")
def engine():
    arch = ARCHS["granite-3-2b"].reduced()
    m = Model(arch, ShardingPolicy(mesh=None), param_dtype=jnp.float32)
    params = m.init(jax.random.key(0))
    return arch, Engine(m, params, EngineConfig(max_batch=4, max_seq=64))


def test_generate_greedy_matches_stepwise(engine):
    """Engine generation equals manual prefill + argmax decode."""
    arch, eng = engine
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (2, 12), 0, arch.vocab_size), np.int32)
    out = eng.generate(prompts, max_new=5)
    logits, cache = eng.model.prefill(eng.params, jnp.asarray(prompts),
                                      max_seq=64)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for i in range(5):
        assert np.array_equal(np.asarray(tok[:, 0]), out[:, i])
        if i < 4:
            logits, cache = eng.model.decode_step(
                eng.params, cache, jnp.int32(12 + i), tok)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]


def test_batcher_launches_on_full_batch(engine):
    arch, eng = engine
    clock = [0.0]
    b = Batcher(eng, timeout_ms=1e9, max_new=3, clock=lambda: clock[0])
    for i in range(4):
        b.submit(ServeRequest(i, np.arange(5, dtype=np.int32) + i,
                              deadline_s=10.0, submitted_s=0.0))
    done = b.pump()
    assert len(done) == 4
    assert all(r.result is not None and r.result.shape == (3,)
               for r in done)


def test_batcher_timeout_partial_launch(engine):
    arch, eng = engine
    clock = [0.0]
    b = Batcher(eng, timeout_ms=50.0, max_new=2, clock=lambda: clock[0])
    b.submit(ServeRequest(0, np.arange(4, dtype=np.int32),
                          deadline_s=10.0, submitted_s=0.0))
    assert b.pump() == []          # not full, not timed out
    clock[0] = 0.2                 # 200 ms later
    done = b.pump()
    assert len(done) == 1


def test_batcher_drops_past_deadline(engine):
    arch, eng = engine
    clock = [5.0]
    b = Batcher(eng, timeout_ms=10.0, clock=lambda: clock[0])
    b.submit(ServeRequest(0, np.arange(4, dtype=np.int32),
                          deadline_s=1.0, submitted_s=0.0))
    assert b.pump() == []
    assert b.dropped == 1
