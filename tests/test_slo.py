"""SLO error-budget plane (DESIGN.md §17): ledger/window algebra, the
burn == violation-rate/budget property against SimMetrics on hooked
runs (fast AND legacy loops), multi-window alert fire/clear semantics,
the SloMonitor mid-run evaluation path, exposition round-trip over the
new families, PushExporter delivery guarantees under a failing sink,
the AuditLog flight recorder, and the violated-request explain() chain
through a chaos storm with mid-bin emergency re-planning."""
import json

import pytest

from repro.chaos import DegradationLadder, EmergencyReplanner
from repro.core.frontend import Frontend
from repro.core.milp import Planner
from repro.core.profiler import Profiler
from repro.hwspec import chaos_cluster
from repro.obs import (Alert, AlertRule, AuditLog, Instrumentation,
                       ListTransport, MetricBatch, MetricsRegistry,
                       OtlpJsonSink, PushExporter, SloLedger, SloMonitor,
                       SloPlane, StatsdSink, parse_exposition, sre_rules)
from repro.reconfig import TransitionPlanner
from repro.runtime import (ClusterRuntime, DomainFailureEvent, Scenario,
                           SimBackend)


@pytest.fixture(scope="module")
def planned_social(social_profiler):
    g, prof = social_profiler
    cfg = Planner(g, prof, s_avail=64, max_tuples_per_task=32,
                  bb_nodes=4, bb_time_s=1.0).plan(15.0)
    assert cfg is not None
    return g, cfg


# ---------------------------------------------------------------------------
# ledger algebra
# ---------------------------------------------------------------------------
def test_ledger_buckets_windows_and_pruning():
    led = SloLedger(bucket_s=0.5, horizon_s=4.0)
    led.record("a", 0.1, 1.0, 0.0)
    led.record("a", 0.4, 1.0, 1.0)     # same bucket folds
    led.record("a", 1.2, 0.0, 2.0)
    assert led.window_counts("a", 10.0, 1.2) == (2.0, 3.0)
    # a narrow window only sees the tail bucket
    assert led.window_counts("a", 0.5, 1.4) == (0.0, 2.0)
    assert led.error_rate("a", 10.0, 1.2) == pytest.approx(3.0 / 5.0)
    # records far in the future prune everything past the horizon
    led.record("a", 100.0, 1.0, 0.0)
    assert led.totals("a") == (1.0, 0.0)
    assert led.apps() == ["a"]
    with pytest.raises(ValueError):
        SloLedger(bucket_s=0.0)
    with pytest.raises(ValueError):
        SloLedger(bucket_s=1.0, horizon_s=0.5)


def test_sre_rules_shape():
    fast, slow = sre_rules(1.0)
    assert fast.name == "latency_fast_burn" and fast.burn_factor == 14.4
    assert fast.short_window_s == pytest.approx(1.0 / 12.0)
    assert slow.long_window_s == pytest.approx(6.0)
    acc = sre_rules(2.0, slo="accuracy")
    assert all(r.slo == "accuracy" for r in acc)
    assert acc[0].name == "accuracy_fast_burn"
    with pytest.raises(ValueError):
        sre_rules(0.0)


# ---------------------------------------------------------------------------
# the §17 property: burn-rate over the whole run == violation_rate /
# budget from the SAME replay's SimMetrics — on BOTH event loops
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fast", [True, False],
                         ids=["fastloop", "legacy"])
def test_burn_equals_simmetrics_violation_rate(planned_social, fast):
    g, cfg = planned_social
    plane = SloPlane(latency_budget=0.05)
    hooks = Instrumentation(slo=plane)
    rt = ClusterRuntime(g, cfg, SimBackend(), seed=3, hooks=hooks,
                        fast=fast)
    m = rt.run(Scenario.poisson(60.0, duration_s=8.0, warmup_s=2.0))
    assert m.completions > 0 and m.dropped > 0
    good, bad = plane.latency.totals("")
    # ledger total == SimMetrics total (completions + fan-weighted
    # drops), bad == violations (missed + dropped) — exact counts
    assert good + bad == m.total_requests
    assert bad == m.violations
    now = plane.latency.last_now
    err = plane.latency.error_rate("", 1e4, now)
    assert err == pytest.approx(m.violation_rate)
    # burn over the full-run window is exactly error/budget
    rule = AlertRule("full_run", long_window_s=1e4, short_window_s=1e4,
                     burn_factor=1e9)
    p2 = SloPlane(latency_budget=0.05, rules=(rule,))
    p2.latency = plane.latency
    p2.evaluate(now)
    reg = MetricsRegistry()
    p2.bind(reg)
    parsed = parse_exposition(reg.render())
    burn = parsed["jigsaw_slo_burn_rate"][
        (("app", ""), ("rule", "full_run"), ("window", "long"))]
    assert burn == pytest.approx(m.violation_rate / 0.05)
    # and 1 - window attainment == violation rate (same replay)
    att = parsed["jigsaw_slo_window_attainment"][
        (("app", ""), ("slo", "latency"))]
    assert 1.0 - att == pytest.approx(m.violation_rate)


def test_accuracy_ledger_tracks_degraded_dispatch(planned_social,
                                                  social_profiler):
    """The accuracy-SLO proxy books every dispatched sub-request exactly
    once (ledger total == jigsaw_served_total), splitting on the
    server's degraded flag AT DISPATCH.  SimMetrics.degraded_served
    reads the flag at batch completion, so the two counts track each
    other but can differ by in-flight ladder moves — the exact parity
    claim lives on the total, not the split."""
    g, cfg = planned_social
    _, prof = social_profiler
    plane = SloPlane()
    hooks = Instrumentation(slo=plane)
    mon = EmergencyReplanner(Frontend(g), planned_for_rps=15.0,
                             hooks=hooks)
    m = ClusterRuntime(
        g, cfg, SimBackend(), seed=0, hooks=hooks, monitor=mon,
        ladder=DegradationLadder(profiler=prof),
    ).run(Scenario.poisson(60.0, duration_s=10.0, warmup_s=1.0))
    assert m.degraded_served > 0, "surge must downshift some streams"
    good, bad = plane.accuracy.totals("")
    assert bad > 0, "downshifted dispatches must land in the bad bucket"
    served = parse_exposition(hooks.registry.render())[
        "jigsaw_served_total"]
    assert good + bad == sum(served.values())
    # dispatch-time vs completion-time attribution differ only by
    # batches whose server the ladder toggled while they were in flight
    assert bad == pytest.approx(m.degraded_served, rel=0.25)


# ---------------------------------------------------------------------------
# alert semantics
# ---------------------------------------------------------------------------
def test_multiwindow_alert_fires_and_clears():
    rule = AlertRule("r", long_window_s=4.0, short_window_s=1.0,
                     burn_factor=6.0, min_requests=5)
    plane = SloPlane(latency_budget=0.05, rules=(rule,), bucket_s=0.25)
    reg = MetricsRegistry()
    plane.bind(reg)
    # healthy traffic: no alert
    for i in range(20):
        plane.record_latency("a", 0.1 * i, missed=False)
    assert plane.evaluate(2.0) == []
    assert not plane.paging("a")
    # sustained 100% errors (burn 20x > 6x) in BOTH windows -> fires
    for i in range(20):
        plane.record_latency("a", 2.0 + 0.1 * i, missed=True)
    firing = plane.evaluate(4.0)
    assert [a.rule for a in firing] == ["r"]
    assert firing[0].burn_short >= 6.0 and firing[0].page
    assert plane.paging("a") and plane.paging() and not plane.paging("b")
    assert plane.first_fired[("r", "a")] == pytest.approx(4.0)
    # good traffic drains the SHORT window -> stops paging, but the
    # first-fired time (the lead-time measurement) is retained
    for i in range(40):
        plane.record_latency("a", 4.0 + 0.05 * i, missed=False)
    assert plane.evaluate(6.0) == []
    assert not plane.paging("a")
    assert plane.first_fired[("r", "a")] == pytest.approx(4.0)
    parsed = parse_exposition(reg.render())
    assert parsed["jigsaw_slo_alerts_fired_total"][
        (("rule", "r"), ("app", "a"))] == 1
    assert parsed["jigsaw_slo_alert_firing"][
        (("rule", "r"), ("app", "a"))] == 0


def test_alert_needs_min_requests_and_both_windows():
    rule = AlertRule("r", long_window_s=4.0, short_window_s=1.0,
                     burn_factor=6.0, min_requests=50)
    plane = SloPlane(latency_budget=0.05, rules=(rule,))
    for i in range(10):       # 100% bad, but only 10 requests
        plane.record_latency("a", 0.1 * i, missed=True)
    assert plane.evaluate(1.0) == []
    # an OLD burst outside the short window must not page (sustained
    # long-window burn alone is not "still happening")
    plane2 = SloPlane(latency_budget=0.05,
                      rules=(AlertRule("r", long_window_s=8.0,
                                       short_window_s=0.5,
                                       burn_factor=6.0, min_requests=5),))
    for i in range(100):
        plane2.record_latency("a", 0.01 * i, missed=True)
    for i in range(10):
        plane2.record_latency("a", 4.0 + 0.1 * i, missed=False)
    assert plane2.evaluate(5.0) == []


def test_alerts_json_and_audit_episode():
    audit = AuditLog()
    plane = SloPlane(rules=(AlertRule("r", long_window_s=2.0,
                                      short_window_s=0.5,
                                      burn_factor=2.0, min_requests=2),),
                     audit=audit)
    for i in range(10):
        plane.record_latency("a", 0.1 * i, missed=True)
    doc = plane.alerts_json(1.0)
    assert doc["alerts"] and doc["alerts"][0]["rule"] == "r"
    assert {r["name"] for r in doc["rules"]} == {"r"}
    assert doc["budgets"]["latency"] == pytest.approx(0.05)
    kinds = [e.kind for e in audit.events]
    assert kinds.count("alert") == 1     # one episode, not per-eval
    plane.alerts_json(1.1)
    assert [e.kind for e in audit.events].count("alert") == 1


def test_slo_monitor_evaluates_midrun_and_delegates(planned_social):
    g, cfg = planned_social

    class _Inner:
        interval_s = 0.5

        def __init__(self):
            self.begun = 0
            self.checks = 0

        def begin_run(self, runtime):
            self.begun += 1

        def check(self, runtime, now, metrics):
            self.checks += 1
            return None

    # default SRE rules on a 1 s base window; a 2% budget makes the
    # sustained ~25% overdrive error rate an unambiguous 6x slow burn
    plane = SloPlane(latency_budget=0.02)
    hooks = Instrumentation(slo=plane)
    inner = _Inner()
    mon = SloMonitor(plane, interval_s=0.5, inner=inner)
    m = ClusterRuntime(g, cfg, SimBackend(), seed=3, hooks=hooks,
                       monitor=mon).run(
        Scenario.poisson(60.0, duration_s=8.0, warmup_s=1.0))
    assert inner.begun == 1 and inner.checks >= 5
    assert m.violation_rate > 6 * 0.02, "overdrive must burn the budget"
    # the monitor cadence caught the burn DURING the run, well before
    # the end-of-bin report
    key = ("latency_slow_burn", "")
    assert key in plane.first_fired
    assert plane.first_fired[key] < 8.0


# ---------------------------------------------------------------------------
# exposition round-trip over the new families
# ---------------------------------------------------------------------------
def test_slo_families_exposition_roundtrip():
    plane = SloPlane()
    hooks = Instrumentation(slo=plane)
    for i in range(30):
        hooks.on_complete("app1", i, 100.0, i % 2 == 0, 0.1 * i)
    text = hooks.registry.render()     # collector evaluates the plane
    parsed = parse_exposition(text)
    for fam in ("jigsaw_slo_burn_rate", "jigsaw_slo_budget_remaining",
                "jigsaw_slo_window_attainment"):
        assert any(dict(k).get("app") == "app1" for k in parsed[fam])
    err = plane.latency.error_rate("app1", 6.0, plane.latency.last_now)
    assert parsed["jigsaw_slo_window_attainment"][
        (("app", "app1"), ("slo", "latency"))] == pytest.approx(1 - err)
    assert parsed["jigsaw_slo_budget_remaining"][
        (("app", "app1"), ("slo", "latency"))] == pytest.approx(
            1 - err / 0.05)


def test_registry_snapshot_matches_exposition():
    plane = SloPlane()
    hooks = Instrumentation(slo=plane)
    for i in range(10):
        hooks.on_complete("a", i, 50.0, False, 0.1 * i)
        hooks.on_drop("a", "t", "staleness", 2, 0.1 * i)
    snap = {(n, labels): v for n, _k, labels, v
            in hooks.registry.snapshot()}
    parsed = parse_exposition(hooks.registry.render())
    assert snap[("jigsaw_completions_total", (("app", "a"),))] == \
        parsed["jigsaw_completions_total"][(("app", "a"),)]
    assert snap[("jigsaw_drops_total",
                 (("app", "a"), ("reason", "staleness")))] == 20.0
    # histograms flatten to _count/_sum in the snapshot
    assert ("jigsaw_request_latency_seconds_count",
            (("app", "a"),)) in snap


# ---------------------------------------------------------------------------
# push exporter delivery guarantees
# ---------------------------------------------------------------------------
class _FlakySink:
    """Fails the first ``fail_n`` emit attempts, then succeeds."""

    def __init__(self, fail_n):
        self.fail_n = fail_n
        self.attempts = 0
        self.batches = []

    def emit(self, batch):
        self.attempts += 1
        if self.attempts <= self.fail_n:
            raise ConnectionError("sink down")
        self.batches.append(batch)


def _exporter(sink, **kw):
    reg = MetricsRegistry()
    reg.counter("t_total", "t", ("app",)).inc(3, "a")
    kw.setdefault("sleep", lambda s: None)
    return reg, PushExporter(reg, sink, **kw)


def test_push_exporter_retries_with_backoff_then_delivers():
    sink = _FlakySink(2)
    delays = []
    reg, exp = _exporter(sink, max_retries=3, backoff_s=0.05,
                         backoff_mult=2.0, sleep=delays.append)
    exp.scrape(now=1.0)
    assert exp.pump() == 1
    assert sink.batches and sink.batches[0].t_s == 1.0
    assert delays == [0.05, 0.1]       # exponential, one per retry
    st = exp.stats()
    assert st["delivered"] == 1 and st["retries"] == 2
    assert st["dropped_failed"] == 0


def test_push_exporter_drops_after_max_retries_and_accounts():
    sink = _FlakySink(10 ** 9)         # never recovers
    reg, exp = _exporter(sink, max_retries=2)
    exp.scrape()
    exp.scrape()
    assert exp.pump() == 0
    st = exp.stats()
    assert st["dropped_failed"] == 2 and st["delivered"] == 0
    assert st["retries"] == 4          # 2 retries per batch
    assert st["enqueued"] == st["delivered"] + st["dropped_overflow"] + \
        st["dropped_failed"] + st["pending"]


def test_push_exporter_bounded_queue_drops_oldest():
    sink = _FlakySink(0)
    reg, exp = _exporter(sink, queue_max=3)
    for i in range(7):
        exp.scrape(now=float(i))
    assert exp.pending() == 3
    st = exp.stats()
    assert st["dropped_overflow"] == 4
    exp.pump()
    # freshest-wins: the delivered batches are the LAST three scrapes
    assert [b.t_s for b in sink.batches] == [4.0, 5.0, 6.0]
    st = exp.stats()
    assert st["enqueued"] == 7 == st["delivered"] + \
        st["dropped_overflow"] + st["dropped_failed"] + st["pending"]


def test_push_sinks_render_registry_snapshot():
    reg = MetricsRegistry()
    reg.counter("t_reqs_total", "t", ("app",)).inc(2, "a")
    reg.gauge("t_depth", "t").set(5)
    batch = MetricBatch(0, 1.5, tuple(reg.snapshot()))
    tr = ListTransport()
    StatsdSink(tr).emit(batch)
    lines = tr.payloads[0].splitlines()
    assert "t_reqs_total:2|c|#app:a" in lines
    assert "t_depth:5|g" in lines
    tr2 = ListTransport()
    OtlpJsonSink(tr2, service_name="svc").emit(batch)
    doc = json.loads(tr2.payloads[0])
    rm = doc["resourceMetrics"][0]
    assert rm["resource"]["attributes"][0]["value"]["stringValue"] == \
        "svc"
    metrics = {m["name"]: m
               for m in rm["scopeMetrics"][0]["metrics"]}
    assert metrics["t_reqs_total"]["sum"]["isMonotonic"] is True
    pt = metrics["t_reqs_total"]["sum"]["dataPoints"][0]
    assert pt["asDouble"] == 2.0
    assert pt["attributes"] == [
        {"key": "app", "value": {"stringValue": "a"}}]
    assert metrics["t_depth"]["gauge"]["dataPoints"][0]["asDouble"] == 5.0


def test_push_exporter_thread_never_blocks_hot_path():
    """The background pump against a dead sink must not stall scrape()
    callers (bounded queue + drop-oldest)."""
    sink = _FlakySink(10 ** 9)
    reg, exp = _exporter(sink, queue_max=2, max_retries=1,
                         backoff_s=0.001, interval_s=0.01,
                         sleep=lambda s: None)
    exp.start()
    try:
        for _ in range(50):
            exp.scrape()
    finally:
        exp.stop(flush=True)
    st = exp.stats()
    assert st["pending"] == 0
    assert st["enqueued"] == st["delivered"] + st["dropped_overflow"] + \
        st["dropped_failed"]


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------
def test_audit_log_bounded_query_and_ndjson_roundtrip():
    log = AuditLog(maxlen=8)
    for i in range(12):
        log.record("replan", float(i), app="a" if i % 2 else "b",
                   trigger="frontend", solve_ms=1.5)
    assert len(log) == 8 and log.evicted == 4
    assert log.events[0].seq == 4      # oldest evicted first
    assert len(log.query(app="a")) == 4
    assert len(log.query(kind="replan", t0=6.0, t1=9.0)) == 4
    assert log.query(kind="nope") == []
    text = log.to_ndjson()
    back = AuditLog.from_ndjson(text)
    assert [e.to_dict() for e in back.events] == \
        [e.to_dict() for e in log.events]
    assert back.to_ndjson() == text
    with pytest.raises(ValueError):
        AuditLog(maxlen=0)


def test_audit_explain_builds_decision_chain():
    log = AuditLog()
    log.record("replan", 1.0, trigger="cold")
    log.record("ladder", 2.0, level=1, previous=0)
    log.record("violation", 3.0, app="a", root_id=7, latency_ms=900.0)
    log.record("replan", 9.0, trigger="frontend")   # AFTER: excluded
    chain = log.explain(7)
    assert [e.kind for e in chain] == ["replan", "ladder", "violation"]
    assert log.explain(12345) == []


def test_violated_request_explains_chaos_decision_chain(social_profiler):
    """End-to-end §17 acceptance: in a domain-kill storm with the
    emergency replanner attached, a violated request's root_id resolves
    through the flight recorder to the decisions that preceded it
    (spike -> emergency_replan -> transition)."""
    g, prof0 = social_profiler
    cluster = chaos_cluster()
    prof = Profiler(g, cluster=cluster)
    kw = dict(max_tuples_per_task=32, bb_nodes=8, bb_time_s=3.0)
    pl = Planner(g, prof, s_avail=cluster.total_units, **kw)
    cfg = pl.plan(30.0)
    assert cfg is not None
    # 40 rps over a 30-rps plan: the kill + overdrive sustain enough
    # drop pressure that the slow-burn rule fires during the run
    storm = Scenario.poisson(40.0, duration_s=16.0,
                             warmup_s=1.0).with_chaos(
        DomainFailureEvent(at_s=3.0, domain="r0"))
    audit = AuditLog(maxlen=1 << 14)
    hooks = Instrumentation(slo=SloPlane(), audit=audit)
    epl = Planner(g, prof, s_avail=cluster.total_units,
                  stickiness=0.05, **kw)
    mon = EmergencyReplanner(Frontend(g), planner=epl,
                             reconfig=TransitionPlanner(cluster, g),
                             planned_for_rps=30.0, hooks=hooks)
    # ONE monitor slot: the SloMonitor evaluates the burn-rate rules on
    # the cadence, then delegates to the emergency replanner
    m = ClusterRuntime(g, cfg, SimBackend(), seed=0, cluster=cluster,
                       monitor=SloMonitor(hooks.slo, interval_s=0.5,
                                          inner=mon),
                       hooks=hooks).run(storm)
    # deadline-driven early drops ARE the violation mode of this
    # simulator (violations = missed + dropped; late completions are
    # pre-empted by the §3.3 early-drop pass)
    assert mon.replans >= 1 and m.dropped > 0
    kinds = {e.kind for e in audit.events}
    assert {"spike", "emergency_replan", "transition",
            "violation"} <= kinds
    # every fan-weighted drop is audited as a violation, root-signed
    viols = [e for e in audit.events if e.kind == "violation"]
    assert sum(e.detail["n"] for e in viols) == m.dropped
    # the emergency replan carries its why (dead capacity) + what (diff)
    er = next(e for e in audit.events if e.kind == "emergency_replan")
    assert er.detail["dead_units"], "rescue must name the dead pools"
    assert er.detail["actions"] >= 1
    # pick a violation AFTER the rescue: its chain contains the rescue
    viol = next(e for e in audit.events
                if e.kind == "violation" and e.t_s > er.t_s)
    assert viol.root_id is not None
    chain = audit.explain(viol.root_id)
    chain_kinds = [e.kind for e in chain]
    assert "emergency_replan" in chain_kinds
    assert "violation" in chain_kinds
    assert all(e.t_s <= viol.t_s + 1e-9 for e in chain)
    # the NDJSON download round-trips the full chain
    back = AuditLog.from_ndjson(audit.to_ndjson())
    assert [e.to_dict() for e in back.explain(viol.root_id)] == \
        [e.to_dict() for e in chain]
    # the storm also lights the burn-rate alert DURING the run
    assert ("latency_slow_burn", "") in hooks.slo.first_fired
