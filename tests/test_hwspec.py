"""Hardware model: DeviceSpec/PartitionScheme/ClusterSpec invariants, the
hw.py shim, and the single-pool regression pins (plans must stay
objective-identical to the pre-hwspec implementation)."""
import pytest

from repro.core import hw
from repro.core.apps import get_app
from repro.core.milp import Planner
from repro.core.profiler import Profiler
from repro.hwspec import (A100_40GB, ClusterSpec, MigScheme, Pool,
                          TorusScheme, TPU_V5E, default_cluster,
                          hetero_cluster)
from repro.sharding.segments import catalogue


# ---------------------------------------------------------------------------
# DeviceSpec + hw shim
# ---------------------------------------------------------------------------
def test_hw_shim_matches_device_spec():
    assert hw.PEAK_FLOPS_BF16 == TPU_V5E.peak_flops["bf16"] == 197e12
    assert hw.PEAK_FLOPS_INT8 == TPU_V5E.peak_flops["int8"] == 394e12
    assert hw.HBM_BYTES == TPU_V5E.hbm_bytes == 16 * 2 ** 30
    assert hw.HBM_BW == TPU_V5E.hbm_bw
    assert hw.ICI_BW_PER_LINK == TPU_V5E.ici_bw_per_link
    assert hw.peak_flops("int8") == TPU_V5E.peak("int8")
    assert hw.peak_flops("bf16") == TPU_V5E.peak("bf16")
    assert hw.param_bytes("int8") == 1 and hw.param_bytes("bf16") == 2


def test_unknown_dtype_falls_back_to_bf16():
    assert A100_40GB.peak("fp8") == A100_40GB.peak_flops["bf16"]


# ---------------------------------------------------------------------------
# schemes
# ---------------------------------------------------------------------------
def test_torus_scheme_reproduces_legacy_catalogue():
    """The default TorusScheme slice set is name/cost/stream-identical to
    the legacy segment catalogue (that is what keeps old tables valid)."""
    legacy = catalogue()
    slices = TorusScheme().slices()
    assert [s.name for s in slices] == [s.name for s in legacy]
    assert [s.cost for s in slices] == [s.chips for s in legacy]
    assert [s.streams for s in slices] == [s.streams for s in legacy]
    assert all(s.devices == s.cost for s in slices)


def test_mig_scheme_slices():
    sch = MigScheme()
    names = {s.name for s in sch.slices()}
    assert "1g.5gb.s1" in names and "7g.40gb.s4" in names
    s7 = sch.slice("7g.40gb.s1")
    assert s7.cost == 7 and s7.devices == 1
    assert s7.compute_fraction == pytest.approx(1.0)
    assert s7.memory_fraction == pytest.approx(1.0)
    s1 = sch.slice("1g.5gb.s2")
    assert s1.cost == 1 and s1.streams == 2
    assert s1.memory_fraction == pytest.approx(1 / 8)
    assert sch.units_per_device == 7 and sch.unopt_cost == 7


def test_cluster_rejects_duplicate_slice_names():
    with pytest.raises(ValueError, match="cluster-unique"):
        ClusterSpec(pools=(
            Pool("a", TPU_V5E, 16, TorusScheme()),
            Pool("b", TPU_V5E, 16, TorusScheme()),
        ))


def test_default_cluster_geometry():
    cl = default_cluster()
    assert len(cl.pools) == 1
    assert cl.pools[0].count == 512            # 2 pods x 16x16
    assert cl.total_units == 512
    pool, sl = cl.find_slice("4x4s2")
    assert pool.name == "v5e" and sl.cost == 16 and sl.streams == 2


def test_hetero_cluster_budgets():
    cl = hetero_cluster(v5e_pods=1, mig_devices=8)
    assert cl.budgets() == {"v5e": 256, "mig": 56}
    pool, sl = cl.find_slice("3g.20gb.s1")
    assert pool.name == "mig" and sl.cost == 3


def test_production_mesh_geometry_derives_from_cluster():
    from repro.launch.mesh import production_geometry
    assert production_geometry() == (2, (16, 16))


# ---------------------------------------------------------------------------
# profiler: per-pool tables
# ---------------------------------------------------------------------------
def test_default_profiler_single_pool(traffic_profiler):
    _, prof = traffic_profiler
    assert {e.pool for e in prof.table.values()} == {"v5e"}
    assert prof.pool_of("1x1s1") == "v5e"


def test_profiler_rejects_cluster_and_segments(traffic_profiler):
    g, _ = traffic_profiler
    with pytest.raises(ValueError):
        Profiler(g, segments=catalogue(), cluster=default_cluster())


def test_mig_slices_have_no_ici_term(social_profiler):
    """A MIG slice is intra-device: its 7g roofline must beat or match a
    multi-chip v5e slice of comparable compute on the collective-bound
    ICI term — concretely, the entry exists and records pool 'mig'."""
    g, _ = social_profiler
    cl = hetero_cluster(v5e_pods=1, mig_devices=2)
    prof = Profiler(g, cluster=cl)
    pools = {e.pool for e in prof.table.values()}
    assert pools == {"v5e", "mig"}
    e = prof.get("caption", "gemma-2b", "7g.40gb.s1", 8)
    assert e is not None and e.pool == "mig" and e.chips == 7


# ---------------------------------------------------------------------------
# single-pool regression pins: the hwspec refactor must not move the
# default plans (values captured on the pre-hwspec implementation)
# ---------------------------------------------------------------------------
PINNED = {
    ("social_media", 10.0): (4, 0.995313415349),
    ("social_media", 60.0): (4, 0.951376684241),
    ("traffic_analysis", 10.0): (34, 0.970279720280),
    ("traffic_analysis", 60.0): (3, 0.941241685144),
}


def test_pool_budgets_terminate_on_dead_capacity():
    """Regression: budgets must terminate (all-zero) when dead capacity
    drives s_avail to/below zero on a multi-pool cluster."""
    g = get_app("social_media")
    cl = hetero_cluster(v5e_pods=1, mig_devices=2)
    prof = Profiler(g, cluster=cl)
    planner = Planner(g, prof, s_avail=cl.total_units)
    for dead in (cl.total_units, cl.total_units + 5):
        planner.s_avail = cl.total_units - dead
        budgets = planner.pool_budgets()
        assert all(b == 0 for b in budgets.values())
    planner.s_avail = cl.total_units - 10
    assert sum(planner.pool_budgets().values()) == cl.total_units - 10


def test_single_pool_mig_controller_places():
    """Regression: a single-pool MIG cluster must place through the MIG
    packer, not the legacy rectangle packer."""
    from repro.core.controller import Controller
    from repro.hwspec import A100_40GB, MigScheme, Pool
    g = get_app("social_media")
    cl = ClusterSpec(pools=(Pool("mig", A100_40GB, 8, MigScheme()),))
    prof = Profiler(g, cluster=cl)
    ctl = Controller(g, prof, s_avail=cl.total_units,
                     planner_kwargs=dict(max_tuples_per_task=32,
                                         bb_nodes=4, bb_time_s=1.0))
    ctl.step(0, 20.0, sim_seconds=1.0)
    pls = ctl.place()
    assert pls is not None and all(p.pool == "mig" for p in pls)


def test_explicit_scheme_unopt_honored():
    """Regression: ExplicitScheme.unopt is the pool's whole unit under
    spatial=False (not the planner's torus unopt_chips knob)."""
    from repro.core.milp import FeatureSet
    from repro.hwspec import ExplicitScheme, Pool, slice_from_segment
    from repro.sharding.segments import SegmentType, SEGMENT_SHAPES
    g = get_app("social_media")
    slices = tuple(slice_from_segment(SegmentType(c, 1, SEGMENT_SHAPES[c]))
                   for c in (1, 2, 4))
    cl = ClusterSpec(pools=(Pool("v5e", TPU_V5E, 64,
                                 ExplicitScheme(slices, unopt=4)),))
    prof = Profiler(g, cluster=cl)
    planner = Planner(g, prof, s_avail=64,
                      features=FeatureSet(True, False, True),
                      max_tuples_per_task=32, bb_nodes=4, bb_time_s=1.0)
    cfg = planner.plan(5.0)
    assert cfg is not None
    for (t, v, s, b), m in cfg.counts.items():
        if m > 0:
            assert cl.find_slice(s)[1].cost == 4


def test_planner_rejects_pool_name_mismatch(traffic_profiler):
    """A planner cluster missing the profiler's pools would give those
    tuples unlimited LP capacity — must fail loud at construction."""
    g, prof = traffic_profiler
    other = ClusterSpec(pools=(Pool("tpu", TPU_V5E, 64, TorusScheme()),))
    with pytest.raises(ValueError, match="lacks pools"):
        Planner(g, prof, s_avail=64, cluster=other)


def test_legacy_unopt_chips_knob_wins_on_explicit_scheme(traffic_profiler):
    """Profiler(segments=...) wraps segments in an ExplicitScheme the
    caller never sees; an explicitly-set Planner.unopt_chips must keep
    governing spatial=False there (pre-hwspec behavior)."""
    from repro.core.milp import FeatureSet
    g, _ = traffic_profiler
    prof = Profiler(g, segments=catalogue())
    planner = Planner(g, prof, s_avail=128, unopt_chips=16,
                      features=FeatureSet(True, False, True),
                      max_tuples_per_task=32, bb_nodes=4, bb_time_s=1.0)
    cfg = planner.plan(10.0)
    assert cfg is not None
    for (t, v, s, b), m in cfg.counts.items():
        if m > 0:
            assert prof.cluster.find_slice(s)[1].cost == 16


def test_num_pods_honored_for_inherited_segments_cluster():
    """Regression: Controller(num_pods=1) with a Profiler(segments=...)
    (inherited ExplicitScheme cluster) must expose exactly one pod of
    packing capacity, as the legacy Placer(num_pods) did."""
    from repro.core.controller import Controller
    g = get_app("social_media")
    prof = Profiler(g, segments=catalogue())
    ctl = Controller(g, prof, s_avail=512, num_pods=1,
                     planner_kwargs=dict(max_tuples_per_task=32,
                                         bb_nodes=4, bb_time_s=1.0))
    assert ctl.cluster.pools[0].count == 256
    from repro.core.placement import make_placer
    assert make_placer(ctl.cluster.pools[0]).pack(["8x8s1"] * 5) is None


def test_multi_pool_place_ids_unique():
    """Regression: concatenated multi-pool placements keep unique ids."""
    from repro.core.controller import Controller
    from repro.hwspec import A100_40GB, MigScheme, Pool, TorusScheme
    g = get_app("social_media")
    cl = ClusterSpec(pools=(
        Pool("v5e", TPU_V5E, 8, TorusScheme(max_chips=4)),
        Pool("mig", A100_40GB, 2, MigScheme()),
    ))
    prof = Profiler(g, cluster=cl)
    ctl = Controller(g, prof, s_avail=cl.total_units, cluster=cl,
                     planner_kwargs=dict(max_tuples_per_task=48,
                                         bb_nodes=8, bb_time_s=2.0))
    ctl.step(0, 300.0, sim_seconds=1.0)
    pls = ctl.place()
    assert pls is not None and len(pls) > 1
    ids = [p.instance_id for p in pls]
    assert len(set(ids)) == len(ids)
    assert {p.pool for p in pls} == {"v5e", "mig"}


def test_explicit_single_pool_budget_capped_at_capacity():
    """Regression: an explicit single-pool cluster caps the MILP budget at
    physical capacity (plan() must not promise slices place() can't
    realize); implicit legacy clusters keep uncapped s_avail."""
    from repro.hwspec import A100_40GB, MigScheme
    g = get_app("social_media")
    cl = ClusterSpec(pools=(Pool("mig", A100_40GB, 8, MigScheme()),))
    planner = Planner(g, Profiler(g, cluster=cl), s_avail=60)
    assert planner.pool_budgets() == {"mig": 56}      # 8 devices x 7g
    legacy = Planner(g, Profiler(g), s_avail=600)
    assert legacy.pool_budgets() == {"v5e": 600}      # implicit: uncapped


def test_rectangle_packer_rejects_shapeless_slice():
    from repro.core.placement import RectanglePlacer
    from repro.hwspec import Slice
    placer = RectanglePlacer(num_pods=1,
                             slices=[Slice(name="a", streams=1, cost=1)])
    with pytest.raises(ValueError, match="no rectangle shape"):
        placer.pack(["a"])


@pytest.mark.parametrize("app,R", sorted(PINNED))
def test_default_plan_objective_identical_to_pre_hwspec(
        app, R, social_profiler, traffic_profiler):
    g, prof = (social_profiler if app == "social_media"
               else traffic_profiler)
    planner = Planner(g, prof, s_avail=128, max_tuples_per_task=32,
                      bb_nodes=4, bb_time_s=1.0)
    cfg = planner.plan(R)
    assert cfg is not None
    slices, a_obj = PINNED[(app, R)]
    assert cfg.slices == slices
    assert cfg.exact_a_obj() == pytest.approx(a_obj, abs=1e-9)
