"""Rectangle bin-packing: no overlap, in-bounds, capacity refusal, and a
hypothesis sweep over random segment mixes."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.placement import POD_SHAPE, Placer
from repro.sharding.segments import SEGMENT_SHAPES, SegmentType, catalogue


def seg_name(chips, streams=1):
    h, w = SEGMENT_SHAPES[chips]
    return f"{h}x{w}s{streams}"


def validate(placements, num_pods):
    grids = [np.zeros(POD_SHAPE, dtype=int) for _ in range(num_pods)]
    for pl in placements:
        assert 0 <= pl.pod < num_pods
        assert pl.row + pl.rows <= POD_SHAPE[0]
        assert pl.col + pl.cols <= POD_SHAPE[1]
        grids[pl.pod][pl.row:pl.row + pl.rows,
                      pl.col:pl.col + pl.cols] += 1
    for gr in grids:
        assert gr.max() <= 1, "overlapping placements"


def test_pack_simple():
    placer = Placer(num_pods=1)
    pls = placer.pack([seg_name(64), seg_name(64), seg_name(64),
                       seg_name(64)])
    assert pls is not None and len(pls) == 4
    validate(pls, 1)
    assert placer.chips_used == 256
    assert placer.utilization() == pytest.approx(1.0)


def test_exact_fill_one_pod():
    placer = Placer(num_pods=1)
    pls = placer.pack([seg_name(64)] * 4)
    assert pls is not None
    assert placer.pods[0].used == 256


def test_capacity_refusal():
    placer = Placer(num_pods=1)
    assert placer.pack([seg_name(64)] * 5) is None


def test_mixed_sizes_fill():
    segs = [seg_name(64), seg_name(32), seg_name(32), seg_name(16)] + \
        [seg_name(1)] * 112
    placer = Placer(num_pods=1)
    pls = placer.pack(segs)
    assert pls is not None
    validate(pls, 1)
    assert placer.chips_used == 64 + 64 + 16 + 112


def test_dead_hosts_avoided():
    dead = [(0, 0, 0), (0, 3, 3)]
    placer = Placer(num_pods=1, dead_hosts=dead)
    pls = placer.pack([seg_name(16)] * 15)   # 240 chips + 2 dead: must fit
    assert pls is not None
    for pl in pls:
        for (p, r, c) in dead:
            inside = (pl.pod == p and pl.row <= r < pl.row + pl.rows
                      and pl.col <= c < pl.col + pl.cols)
            assert not inside


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(sorted(SEGMENT_SHAPES)), min_size=1,
                max_size=40))
def test_random_mixes_valid_or_refused(chip_list):
    placer = Placer(num_pods=2)
    pls = placer.pack([seg_name(c) for c in chip_list])
    total = sum(chip_list)
    if pls is not None:
        validate(pls, 2)
        assert len(pls) == len(chip_list)
        assert placer.chips_used == total
    else:
        # refusal is only legitimate when demand exceeds capacity or
        # fragmentation — power-of-two aligned shapes can always pack
        # when the total fits, so refusal implies total > capacity
        assert total > 2 * 256


def test_power_of_two_packing_is_tight():
    """Aligned power-of-two rectangles never fragment: any mix whose chip
    total <= pod capacity packs."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        chips, total = [], 0
        while True:
            c = int(rng.choice(sorted(SEGMENT_SHAPES)))
            if total + c > 256:
                break
            chips.append(c)
            total += c
        placer = Placer(num_pods=1)
        # sort-desc first-fit on aligned anchors must succeed
        assert placer.pack([seg_name(c) for c in chips]) is not None
