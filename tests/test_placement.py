"""Packer properties behind the Placer protocol: the 2-D rectangle packer
(no overlap, in-bounds, dead-host avoidance, capacity refusal) and the MIG
slice packer (placement-rule alignment, per-device g-budget conservation),
each with a hypothesis sweep over random mixes."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.placement import (MigSlicePacker, POD_SHAPE, Placer,
                                  PlacerProtocol, RectanglePlacer,
                                  make_placer)
from repro.hwspec import (A100_40GB, MigScheme, Pool, TorusScheme, TPU_V5E)
from repro.sharding.segments import SEGMENT_SHAPES, SegmentType, catalogue


def seg_name(chips, streams=1):
    h, w = SEGMENT_SHAPES[chips]
    return f"{h}x{w}s{streams}"


def validate(placements, num_pods):
    grids = [np.zeros(POD_SHAPE, dtype=int) for _ in range(num_pods)]
    for pl in placements:
        assert 0 <= pl.pod < num_pods
        assert pl.row + pl.rows <= POD_SHAPE[0]
        assert pl.col + pl.cols <= POD_SHAPE[1]
        grids[pl.pod][pl.row:pl.row + pl.rows,
                      pl.col:pl.col + pl.cols] += 1
    for gr in grids:
        assert gr.max() <= 1, "overlapping placements"


def test_pack_simple():
    placer = Placer(num_pods=1)
    pls = placer.pack([seg_name(64), seg_name(64), seg_name(64),
                       seg_name(64)])
    assert pls is not None and len(pls) == 4
    validate(pls, 1)
    assert placer.chips_used == 256
    assert placer.utilization() == pytest.approx(1.0)


def test_exact_fill_one_pod():
    placer = Placer(num_pods=1)
    pls = placer.pack([seg_name(64)] * 4)
    assert pls is not None
    assert placer.pods[0].used == 256


def test_capacity_refusal():
    placer = Placer(num_pods=1)
    assert placer.pack([seg_name(64)] * 5) is None


def test_mixed_sizes_fill():
    segs = [seg_name(64), seg_name(32), seg_name(32), seg_name(16)] + \
        [seg_name(1)] * 112
    placer = Placer(num_pods=1)
    pls = placer.pack(segs)
    assert pls is not None
    validate(pls, 1)
    assert placer.chips_used == 64 + 64 + 16 + 112


def test_dead_hosts_avoided():
    dead = [(0, 0, 0), (0, 3, 3)]
    placer = Placer(num_pods=1, dead_hosts=dead)
    pls = placer.pack([seg_name(16)] * 15)   # 240 chips + 2 dead: must fit
    assert pls is not None
    for pl in pls:
        for (p, r, c) in dead:
            inside = (pl.pod == p and pl.row <= r < pl.row + pl.rows
                      and pl.col <= c < pl.col + pl.cols)
            assert not inside


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(sorted(SEGMENT_SHAPES)), min_size=1,
                max_size=40))
def test_random_mixes_valid_or_refused(chip_list):
    placer = Placer(num_pods=2)
    pls = placer.pack([seg_name(c) for c in chip_list])
    total = sum(chip_list)
    if pls is not None:
        validate(pls, 2)
        assert len(pls) == len(chip_list)
        assert placer.chips_used == total
    else:
        # refusal is only legitimate when demand exceeds capacity or
        # fragmentation — power-of-two aligned shapes can always pack
        # when the total fits, so refusal implies total > capacity
        assert total > 2 * 256


def test_power_of_two_packing_is_tight():
    """Aligned power-of-two rectangles never fragment: any mix whose chip
    total <= pod capacity packs."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        chips, total = [], 0
        while True:
            c = int(rng.choice(sorted(SEGMENT_SHAPES)))
            if total + c > 256:
                break
            chips.append(c)
            total += c
        placer = Placer(num_pods=1)
        # sort-desc first-fit on aligned anchors must succeed
        assert placer.pack([seg_name(c) for c in chips]) is not None


# ---------------------------------------------------------------------------
# protocol + hypothesis properties over BOTH packers (ISSUE 3 satellite)
# ---------------------------------------------------------------------------
def test_placer_protocol_conformance():
    assert isinstance(RectanglePlacer(num_pods=1), PlacerProtocol)
    assert isinstance(MigSlicePacker(1, MigScheme()), PlacerProtocol)
    assert Placer is RectanglePlacer      # historical alias


def test_make_placer_dispatches_on_scheme():
    rect = make_placer(Pool("v5e", TPU_V5E, 512, TorusScheme()))
    assert isinstance(rect, RectanglePlacer) and rect.num_pods == 2
    mig = make_placer(Pool("mig", A100_40GB, 4, MigScheme()))
    assert isinstance(mig, MigSlicePacker) and mig.num_devices == 4


def test_make_placer_masks_partial_pod():
    """A torus pool smaller than one pod only exposes its own chips: the
    packer must pack exactly up to pool.count and refuse beyond it."""
    pool = Pool("v5e", TPU_V5E, 8, TorusScheme(max_chips=4))
    pls = make_placer(pool).pack([seg_name(4), seg_name(4)])   # 8 chips
    assert pls is not None
    validate(pls, 1)
    assert make_placer(pool).pack([seg_name(4)] * 3) is None   # 12 > 8
    # power-of-two counts keep an aligned rectangle free: 2x2s pack tight
    pls = make_placer(pool).pack([seg_name(1)] * 8)
    assert pls is not None and len(pls) == 8
    # non-power-of-two counts keep a rectangle too (12 -> 2x6), so the
    # multi-row slices the MILP budgets remain placeable
    pool12 = Pool("v5e", TPU_V5E, 12, TorusScheme(max_chips=4))
    pls = make_placer(pool12).pack([seg_name(4)] * 3)          # 12 chips
    assert pls is not None
    validate(pls, 1)
    assert make_placer(pool12).pack([seg_name(4)] * 4) is None  # 16 > 12


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(sorted(SEGMENT_SHAPES)), min_size=1,
                max_size=30),
       st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15)),
                max_size=6))
def test_rectangles_route_around_dead_hosts(chip_list, dead_cells):
    """Any successful packing avoids every dead chip (and stays overlap-
    free / in-bounds) regardless of where the failures landed."""
    dead = [(0, r, c) for (r, c) in set(dead_cells)]
    placer = Placer(num_pods=1, dead_hosts=dead)
    pls = placer.pack([seg_name(c) for c in chip_list])
    if pls is None:
        return
    validate(pls, 1)
    for pl in pls:
        for (p, r, c) in dead:
            inside = (pl.pod == p and pl.row <= r < pl.row + pl.rows
                      and pl.col <= c < pl.col + pl.cols)
            assert not inside, (pl, (r, c))


MIG_SCHEME = MigScheme()
MIG_NAMES = sorted({s.name for s in MIG_SCHEME.slices()})


def validate_mig(placements, num_devices, dead=()):
    scheme = MIG_SCHEME
    slots = [np.zeros(scheme.total_mem_slots, dtype=int)
             for _ in range(num_devices)]
    g_used = [0] * num_devices
    for pl in placements:
        sl = scheme.slice(pl.segment)
        assert 0 <= pl.pod < num_devices
        assert pl.pod not in dead, "placed on a dead device"
        assert pl.row in sl.starts, "start offset violates placement rule"
        assert pl.row + sl.mem_slots <= scheme.total_mem_slots
        slots[pl.pod][pl.row:pl.row + sl.mem_slots] += 1
        g_used[pl.pod] += sl.cost
    for arr in slots:
        assert arr.max(initial=0) <= 1, "overlapping memory slots"
    for gu in g_used:
        assert gu <= scheme.total_g, "per-device g budget exceeded"


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(MIG_NAMES), min_size=1, max_size=24),
       st.integers(1, 4),
       st.lists(st.integers(0, 3), max_size=2))
def test_mig_random_mixes_valid_or_refused(names, num_devices, dead_list):
    dead = {d for d in dead_list if d < num_devices}
    if len(dead) == num_devices:
        dead.pop()                      # keep at least one live device
    packer = MigSlicePacker(num_devices, MIG_SCHEME, dead_hosts=dead)
    pls = packer.pack(list(names))
    if pls is None:
        # refusal is only legitimate when the mix cannot fit the live
        # compute budget exactly-fragmentation-free is NOT guaranteed for
        # MIG (alignment holes are real on A100s too), so only assert the
        # trivial-fit direction: a single small slice always packs
        assert len(names) > 1 or MIG_SCHEME.slice(names[0]).cost > 7
        return
    assert len(pls) == len(names)
    validate_mig(pls, num_devices, dead)


def test_mig_budget_refusal():
    packer = MigSlicePacker(1, MIG_SCHEME)
    assert packer.pack(["4g.20gb.s1", "4g.20gb.s1"]) is None  # 8g > 7g
    packer = MigSlicePacker(1, MIG_SCHEME)
    assert packer.pack(["7g.40gb.s1"] * 2) is None


def test_mig_placement_rules_enforced():
    """3g+3g fills both aligned halves; a further 1g must be refused even
    though 1 g-unit of compute remains (memory slots are exhausted)."""
    packer = MigSlicePacker(1, MIG_SCHEME)
    pls = packer.pack(["3g.20gb.s1", "3g.20gb.s1"])
    assert pls is not None
    assert sorted(pl.row for pl in pls) == [0, 4]
    assert packer.pack(["1g.5gb.s1"]) is None


def test_mig_dead_devices_avoided():
    packer = MigSlicePacker(3, MIG_SCHEME, dead_hosts=[1])
    pls = packer.pack(["7g.40gb.s1", "7g.40gb.s1"])
    assert pls is not None
    assert sorted(pl.pod for pl in pls) == [0, 2]
    validate_mig(pls, 3, dead={1})


def test_mig_streams_share_one_slice():
    """Stream multiplicity is concurrency on ONE slice, not extra slices:
    7 single-stream 1g instances fill a device exactly, regardless of s."""
    for suffix in ("s1", "s4"):
        packer = MigSlicePacker(1, MIG_SCHEME)
        pls = packer.pack([f"1g.5gb.{suffix}"] * 7)
        assert pls is not None and packer.g_used[0] == 7
        packer2 = MigSlicePacker(1, MIG_SCHEME)
        assert packer2.pack([f"1g.5gb.{suffix}"] * 8) is None
