"""Serving front door (DESIGN.md §14): in-process AsyncGateway
end-to-end over two apps, ladder admission at the door, and the stdlib
HTTP server (submit / stream / metrics / trace) on an ephemeral port.

All async tests run through ``asyncio.run`` directly — no pytest-asyncio
in the image.  Gateways run time-compressed (``time_scale < 1``) so a
multi-second simulated serve finishes in a fraction of a wall second;
scales are chosen gentle enough that event-loop overhead (amplified by
1/time_scale in simulated terms) does not flood the deadline budget.
"""
import asyncio
import json

import pytest

from repro.core.dispatch import QueuedRequest
from repro.core.milp import Planner
from repro.gateway import (AdmissionRejected, AsyncGateway,
                           GatewayHTTPServer, direct_submitter,
                           http_submitter, open_loop)
from repro.obs import (Instrumentation, Tracer, parse_exposition,
                       validate_chrome_trace)


@pytest.fixture(scope="module")
def planned_apps(social_profiler, traffic_profiler):
    out = {}
    for name, (g, prof) in (("social_media", social_profiler),
                            ("traffic_analysis", traffic_profiler)):
        cfg = Planner(g, prof, s_avail=64, max_tuples_per_task=32,
                      bb_nodes=4, bb_time_s=1.0).plan(30.0)
        assert cfg is not None
        out[name] = (g, cfg)
    return out


def test_gateway_end_to_end_two_apps(planned_apps):
    """Open-loop load over both apps: every submitted request resolves,
    the scraped counters are self-consistent with the load report, and
    completed requests carry one hop span per task executed."""
    hooks = Instrumentation(tracer=Tracer())

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=0.2)
        await gw.start()
        try:
            report = await open_loop(
                direct_submitter(gw),
                {"social_media": 8.0, "traffic_analysis": 8.0},
                duration_s=3.0, seed=1, time_scale=gw.time_scale)
        finally:
            await gw.stop()
        return gw, report

    gw, report = asyncio.run(drive())
    d = report.to_dict()
    tot = d["total"]
    assert tot["submitted"] > 10
    # every submission resolved one way: ok, dropped, or rejected
    assert tot["ok"] + tot["dropped"] + tot["rejected"] == tot["submitted"]
    assert tot["errors"] == 0
    assert tot["ok"] > 0 and tot["attainment"] > 0.5
    assert not gw._roots, "no request may leak in the root table"

    parsed = parse_exposition(hooks.registry.render())
    arrivals = parsed["jigsaw_arrivals_total"]
    for app in planned_apps:
        st = d["apps"][app]
        accepted = st["submitted"] - st["rejected"]
        assert arrivals.get((("app", app),), 0) == accepted
    # completions counts roots finalized at a leaf: every fully-ok root
    # plus the partially-dropped ones whose last hop still completed
    comp = sum(parsed.get("jigsaw_completions_total", {}).values())
    assert tot["ok"] <= comp <= tot["ok"] + tot["dropped"]

    # trace: valid chrome JSON; a completed root has >= 1 hop span and
    # matching queue/service sub-spans
    events = validate_chrome_trace(hooks.tracer.chrome_trace())
    assert events
    roots_with_hops = {s.root_id for s in hooks.tracer.spans_for_root(0)}
    for rid in range(tot["submitted"]):
        hops = hooks.tracer.spans_for_root(rid, cat="hop")
        if hops:
            assert len(hooks.tracer.spans_for_root(rid, "queue")) == \
                len(hops)
            assert len(hooks.tracer.spans_for_root(rid, "service")) == \
                len(hops)
            break
    else:
        pytest.fail("no root produced hop spans")


def test_gateway_admission_rejects_on_full_queue(planned_apps):
    """The level-1 ladder rung guards the door: an entry queue past the
    SLO-feasible depth refuses new submissions with a 'admission'."""
    hooks = Instrumentation()

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=1.0)
        # stuff the entry queue well past any feasible cap — without
        # starting dispatchers, so the backlog cannot drain
        app = "social_media"
        g, _ = planned_apps[app]
        qt = f"{app}::{g.entry}"
        now = gw.now()
        gw.queues[qt].extend(
            QueuedRequest(10_000 + i, 10_000 + i, qt, now, now + 10.0)
            for i in range(10_000))
        with pytest.raises(AdmissionRejected) as ei:
            await gw.submit(app)
        assert ei.value.reason == "admission"
        # the other app's door stays open
        gr = await gw.submit("traffic_analysis")
        assert gr.root_id >= 0

    asyncio.run(drive())
    parsed = parse_exposition(hooks.registry.render())
    rejects = parsed["jigsaw_admission_rejects_total"]
    assert rejects[(("app", "social_media"),)] == 1
    assert parsed["jigsaw_drops_total"][
        (("app", "social_media"), ("reason", "admission"))] == 1


def test_gateway_quota_rejects_over_contracted_rate(planned_apps):
    """The per-app token bucket refuses arrivals beyond the contracted
    rps with reason 'quota' — BEFORE the ladder's load gate, and only
    for the quota'd app."""
    hooks = Instrumentation()

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=1.0,
                          quotas={"social_media": 0.01}, quota_burst=2.0)
        # the bucket banks one burst at t=0: 2 admits, then refusal
        await gw.submit("social_media")
        await gw.submit("social_media")
        with pytest.raises(AdmissionRejected) as ei:
            await gw.submit("social_media")
        assert ei.value.reason == "quota"
        # the un-quota'd app's door stays open
        gr = await gw.submit("traffic_analysis")
        assert gr.root_id >= 0

    asyncio.run(drive())
    parsed = parse_exposition(hooks.registry.render())
    assert parsed["jigsaw_admission_rejects_total"][
        (("app", "social_media"),)] == 1
    assert parsed["jigsaw_drops_total"][
        (("app", "social_media"), ("reason", "quota"))] == 1


def test_gateway_quota_unknown_app_fails_loud(planned_apps):
    with pytest.raises(ValueError, match="quota for unknown app"):
        AsyncGateway(planned_apps, seed=0, quotas={"nope": 1.0})


def test_gateway_retry_on_drop(planned_apps):
    """retry_drops resubmits the FIRST shed of a hop (deadline budget
    left) instead of failing the root; the second shed is final, and a
    completed retry is counted as a success."""
    hooks = Instrumentation()

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=1.0, retry_drops=True)
        app = "social_media"
        g, _ = planned_apps[app]
        qt = f"{app}::{g.entry}"

        # --- first drop: retried, root stays alive ------------------
        gr = await gw.submit(app)
        req = gw.queues[qt].pop()
        now = gw.now()
        retry = gw._drop(req, qt, "staleness", now)
        assert retry is not None and retry.req_id == req.req_id
        assert gr.retries == 1 and gr.dropped == 0
        assert not gr.done.is_set()

        # --- second drop of the same hop: final ---------------------
        final = gw._drop(retry, qt, "staleness", gw.now())
        assert final is None
        assert gr.dropped == 1 and gr.done.is_set()
        assert gr.outcome["status"] == "dropped"
        assert gr.outcome["retries"] == 1 and gr.outcome["retry_ok"] == 0

        # --- retried hop that completes counts a success ------------
        gr2 = await gw.submit(app)
        req2 = gw.queues[qt].pop()
        retry2 = gw._drop(req2, qt, "staleness", gw.now())
        assert retry2 is not None and gr2.retries == 1
        leaf = next(t for t in g.tasks if not g.successors(t))
        srv = gw.by_task[f"{app}::{leaf}"][0]
        gw._complete_hop(retry2, srv, gw.now())
        assert gr2.retry_ok == 1 and gr2.done.is_set()
        assert gr2.outcome["status"] == "ok"
        assert gr2.outcome["retry_ok"] == 1

        # --- past the deadline there is nothing left to retry -------
        gr3 = await gw.submit(app)
        req3 = gw.queues[qt].pop()
        dead = gw._drop(req3, qt, "deadline", req3.deadline + 1.0)
        assert dead is None and gr3.outcome["status"] == "dropped"
        assert gr3.retries == 0

    asyncio.run(drive())
    parsed = parse_exposition(hooks.registry.render())
    assert parsed["jigsaw_gateway_retries_total"][
        (("app", "social_media"),)] == 2
    assert parsed["jigsaw_gateway_retry_success_total"][
        (("app", "social_media"),)] == 1
    # only FINAL sheds count as drops: 2 retried first-sheds excluded
    assert parsed["jigsaw_drops_total"][
        (("app", "social_media"), ("reason", "staleness"))] == 1
    assert parsed["jigsaw_drops_total"][
        (("app", "social_media"), ("reason", "deadline"))] == 1


def test_gateway_unknown_app_fails_loud(planned_apps):
    async def drive():
        gw = AsyncGateway(planned_apps, seed=0)
        with pytest.raises(KeyError, match="unknown app"):
            await gw.submit("nope")

    asyncio.run(drive())


def test_http_server_smoke(planned_apps):
    """Boot the stdlib HTTP server on an ephemeral port and exercise
    every route over real sockets: healthz, submit (unary + streamed
    NDJSON), /metrics exposition, /trace JSON, /alerts, /audit NDJSON,
    and 404 handling."""
    from repro.obs import AuditLog, SloPlane

    hooks = Instrumentation(tracer=Tracer(), slo=SloPlane(),
                            audit=AuditLog())

    async def fetch(port, method, path, body=b""):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        try:
            writer.write(
                f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n".encode() + body)
            await writer.drain()
            raw = await reader.read()
        finally:
            writer.close()
            await writer.wait_closed()
        head, _, payload = raw.partition(b"\r\n\r\n")
        return int(head.split(b" ", 2)[1]), head, payload

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=0.2)
        srv = GatewayHTTPServer(gw, hooks, port=0)
        await srv.start()
        try:
            port = srv.port
            status, _, body = await fetch(port, "GET", "/healthz")
            assert status == 200
            health = json.loads(body)
            assert set(health["apps"]) == set(planned_apps)

            # unary submit resolves to the final outcome document
            out = await http_submitter(f"http://127.0.0.1:{port}")(
                "social_media")
            assert out["status"] in ("ok", "dropped")
            assert out["event"] == "done"

            # streamed submit yields NDJSON hop lines ending in done
            status, head, payload = await fetch(
                port, "POST", "/v1/social_media/submit?stream=1")
            assert status == 200
            assert b"chunked" in head.lower()
            lines = [json.loads(ln) for ln in _dechunk(payload).strip()
                     .split(b"\n")]
            assert lines[-1]["event"] == "done"
            assert all(ln["event"] in ("hop", "drop", "done")
                       for ln in lines)

            status, _, body = await fetch(port, "GET", "/metrics")
            assert status == 200
            parsed = parse_exposition(body.decode())
            assert sum(parsed["jigsaw_arrivals_total"].values()) >= 2

            status, _, body = await fetch(port, "GET", "/trace")
            assert status == 200
            validate_chrome_trace(json.loads(body))

            # SLO alert state: rules are listed even when nothing fires
            status, _, body = await fetch(port, "GET", "/alerts")
            assert status == 200
            alerts = json.loads(body)
            assert {r["name"] for r in alerts["rules"]} >= {
                "latency_fast_burn", "latency_slow_burn"}
            assert isinstance(alerts["alerts"], list)

            # flight recorder: NDJSON, every line a well-formed event
            status, head, body = await fetch(port, "GET", "/audit")
            assert status == 200
            assert b"ndjson" in head.lower()
            for ln in body.decode().splitlines():
                ev = json.loads(ln)
                assert {"seq", "t_s", "kind"} <= set(ev)

            status, _, _ = await fetch(port, "GET", "/no/such/route")
            assert status == 404
            status, _, _ = await fetch(port, "POST", "/v1/nope/submit")
            assert status == 404
        finally:
            await srv.stop()

    asyncio.run(drive())


def _dechunk(payload: bytes) -> bytes:
    """Decode an HTTP/1.1 chunked body."""
    out, rest = [], payload
    while rest:
        size_line, _, rest = rest.partition(b"\r\n")
        size = int(size_line, 16)
        if size == 0:
            break
        out.append(rest[:size])
        rest = rest[size + 2:]
    return b"".join(out)
