"""Serving front door (DESIGN.md §14): in-process AsyncGateway
end-to-end over two apps, ladder admission at the door, and the stdlib
HTTP server (submit / stream / metrics / trace) on an ephemeral port.

All async tests run through ``asyncio.run`` directly — no pytest-asyncio
in the image.  Gateways run time-compressed (``time_scale < 1``) so a
multi-second simulated serve finishes in a fraction of a wall second;
scales are chosen gentle enough that event-loop overhead (amplified by
1/time_scale in simulated terms) does not flood the deadline budget.
"""
import asyncio
import json

import pytest

from repro.core.dispatch import QueuedRequest
from repro.core.milp import Planner
from repro.gateway import (AdmissionRejected, AsyncGateway,
                           GatewayHTTPServer, direct_submitter,
                           http_submitter, open_loop)
from repro.obs import (Instrumentation, Tracer, parse_exposition,
                       validate_chrome_trace)


@pytest.fixture(scope="module")
def planned_apps(social_profiler, traffic_profiler):
    out = {}
    for name, (g, prof) in (("social_media", social_profiler),
                            ("traffic_analysis", traffic_profiler)):
        cfg = Planner(g, prof, s_avail=64, max_tuples_per_task=32,
                      bb_nodes=4, bb_time_s=1.0).plan(30.0)
        assert cfg is not None
        out[name] = (g, cfg)
    return out


def test_gateway_end_to_end_two_apps(planned_apps):
    """Open-loop load over both apps: every submitted request resolves,
    the scraped counters are self-consistent with the load report, and
    completed requests carry one hop span per task executed."""
    hooks = Instrumentation(tracer=Tracer())

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=0.2)
        await gw.start()
        try:
            report = await open_loop(
                direct_submitter(gw),
                {"social_media": 8.0, "traffic_analysis": 8.0},
                duration_s=3.0, seed=1, time_scale=gw.time_scale)
        finally:
            await gw.stop()
        return gw, report

    gw, report = asyncio.run(drive())
    d = report.to_dict()
    tot = d["total"]
    assert tot["submitted"] > 10
    # every submission resolved one way: ok, dropped, or rejected
    assert tot["ok"] + tot["dropped"] + tot["rejected"] == tot["submitted"]
    assert tot["errors"] == 0
    assert tot["ok"] > 0 and tot["attainment"] > 0.5
    assert not gw._roots, "no request may leak in the root table"

    parsed = parse_exposition(hooks.registry.render())
    arrivals = parsed["jigsaw_arrivals_total"]
    for app in planned_apps:
        st = d["apps"][app]
        accepted = st["submitted"] - st["rejected"]
        assert arrivals.get((("app", app),), 0) == accepted
    # completions counts roots finalized at a leaf: every fully-ok root
    # plus the partially-dropped ones whose last hop still completed
    comp = sum(parsed.get("jigsaw_completions_total", {}).values())
    assert tot["ok"] <= comp <= tot["ok"] + tot["dropped"]

    # trace: valid chrome JSON; a completed root has >= 1 hop span and
    # matching queue/service sub-spans
    events = validate_chrome_trace(hooks.tracer.chrome_trace())
    assert events
    roots_with_hops = {s.root_id for s in hooks.tracer.spans_for_root(0)}
    for rid in range(tot["submitted"]):
        hops = hooks.tracer.spans_for_root(rid, cat="hop")
        if hops:
            assert len(hooks.tracer.spans_for_root(rid, "queue")) == \
                len(hops)
            assert len(hooks.tracer.spans_for_root(rid, "service")) == \
                len(hops)
            break
    else:
        pytest.fail("no root produced hop spans")


def test_gateway_admission_rejects_on_full_queue(planned_apps):
    """The level-1 ladder rung guards the door: an entry queue past the
    SLO-feasible depth refuses new submissions with a 'admission'."""
    hooks = Instrumentation()

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=1.0)
        # stuff the entry queue well past any feasible cap — without
        # starting dispatchers, so the backlog cannot drain
        app = "social_media"
        g, _ = planned_apps[app]
        qt = f"{app}::{g.entry}"
        now = gw.now()
        gw.queues[qt].extend(
            QueuedRequest(10_000 + i, 10_000 + i, qt, now, now + 10.0)
            for i in range(10_000))
        with pytest.raises(AdmissionRejected) as ei:
            await gw.submit(app)
        assert ei.value.reason == "admission"
        # the other app's door stays open
        gr = await gw.submit("traffic_analysis")
        assert gr.root_id >= 0

    asyncio.run(drive())
    parsed = parse_exposition(hooks.registry.render())
    rejects = parsed["jigsaw_admission_rejects_total"]
    assert rejects[(("app", "social_media"),)] == 1
    assert parsed["jigsaw_drops_total"][
        (("app", "social_media"), ("reason", "admission"))] == 1


def test_gateway_unknown_app_fails_loud(planned_apps):
    async def drive():
        gw = AsyncGateway(planned_apps, seed=0)
        with pytest.raises(KeyError, match="unknown app"):
            await gw.submit("nope")

    asyncio.run(drive())


def test_http_server_smoke(planned_apps):
    """Boot the stdlib HTTP server on an ephemeral port and exercise
    every route over real sockets: healthz, submit (unary + streamed
    NDJSON), /metrics exposition, /trace JSON, and 404 handling."""
    hooks = Instrumentation(tracer=Tracer())

    async def fetch(port, method, path, body=b""):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        try:
            writer.write(
                f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n".encode() + body)
            await writer.drain()
            raw = await reader.read()
        finally:
            writer.close()
            await writer.wait_closed()
        head, _, payload = raw.partition(b"\r\n\r\n")
        return int(head.split(b" ", 2)[1]), head, payload

    async def drive():
        gw = AsyncGateway(planned_apps, seed=0, hooks=hooks,
                          time_scale=0.2)
        srv = GatewayHTTPServer(gw, hooks, port=0)
        await srv.start()
        try:
            port = srv.port
            status, _, body = await fetch(port, "GET", "/healthz")
            assert status == 200
            health = json.loads(body)
            assert set(health["apps"]) == set(planned_apps)

            # unary submit resolves to the final outcome document
            out = await http_submitter(f"http://127.0.0.1:{port}")(
                "social_media")
            assert out["status"] in ("ok", "dropped")
            assert out["event"] == "done"

            # streamed submit yields NDJSON hop lines ending in done
            status, head, payload = await fetch(
                port, "POST", "/v1/social_media/submit?stream=1")
            assert status == 200
            assert b"chunked" in head.lower()
            lines = [json.loads(ln) for ln in _dechunk(payload).strip()
                     .split(b"\n")]
            assert lines[-1]["event"] == "done"
            assert all(ln["event"] in ("hop", "drop", "done")
                       for ln in lines)

            status, _, body = await fetch(port, "GET", "/metrics")
            assert status == 200
            parsed = parse_exposition(body.decode())
            assert sum(parsed["jigsaw_arrivals_total"].values()) >= 2

            status, _, body = await fetch(port, "GET", "/trace")
            assert status == 200
            validate_chrome_trace(json.loads(body))

            status, _, _ = await fetch(port, "GET", "/no/such/route")
            assert status == 404
            status, _, _ = await fetch(port, "POST", "/v1/nope/submit")
            assert status == 404
        finally:
            await srv.stop()

    asyncio.run(drive())


def _dechunk(payload: bytes) -> bytes:
    """Decode an HTTP/1.1 chunked body."""
    out, rest = [], payload
    while rest:
        size_line, _, rest = rest.partition(b"\r\n")
        size = int(size_line, 16)
        if size == 0:
            break
        out.append(rest[:size])
        rest = rest[size + 2:]
    return b"".join(out)
