"""Task graphs + registration handler: DAG validation, path enumeration,
Eq. 5 demand propagation, registration errors."""
import pytest

from repro.core.apps import APPS, get_app
from repro.core.registry import RegistrationError, register
from repro.core.taskgraph import Task, TaskGraph, Variant


def V(name="v", arch="gemma-2b", acc=0.9):
    return Variant(name, arch, accuracy=acc)


def test_apps_register_cleanly():
    for name in APPS:
        reg = register(get_app(name))
        assert reg.profiler.table


def test_paths_and_depth():
    g = get_app("traffic_analysis")
    assert sorted(g.paths) == [("detect", "person_attrs"),
                               ("detect", "vehicle_attrs")]
    assert g.depth == 1
    assert get_app("ar_assistant").paths == [("detect", "caption", "tts")]
    assert get_app("ar_assistant").depth == 2


def test_cycle_rejected():
    with pytest.raises(ValueError, match="cycle"):
        TaskGraph("bad", {"a": Task("a", (V(),)), "b": Task("b", (V(),))},
                  [("a", "b"), ("b", "a")])


def test_multiple_entries_rejected():
    with pytest.raises(ValueError, match="entry"):
        TaskGraph("bad", {"a": Task("a", (V(),)), "b": Task("b", (V(),)),
                          "c": Task("c", (V(),))},
                  [("a", "c"), ("b", "c")])


def test_unknown_edge_task_rejected():
    with pytest.raises(ValueError, match="unknown"):
        TaskGraph("bad", {"a": Task("a", (V(),))}, [("a", "zzz")])


def test_demand_propagation_eq5():
    g = get_app("traffic_analysis")
    d = g.demand_at_tasks(100.0)   # most-accurate detect: cars 1.5, ppl 2.0
    assert d["detect"] == 100.0
    assert d["vehicle_attrs"] == pytest.approx(150.0)
    assert d["person_attrs"] == pytest.approx(200.0)
    # observed fbar overrides (paper §3.2)
    d2 = g.demand_at_tasks(100.0, {("detect", "vehicle_attrs"): 3.0})
    assert d2["vehicle_attrs"] == pytest.approx(300.0)


def test_demand_propagation_chain():
    g = get_app("ar_assistant")
    d = g.demand_at_tasks(10.0)
    assert d["caption"] == pytest.approx(12.0)   # 1.2 fan-out
    assert d["tts"] == pytest.approx(12.0)


def test_register_unknown_arch_rejected():
    t = Task("a", (Variant("v", "not-an-arch", accuracy=0.9),))
    g = TaskGraph("g", {"a": t}, [])
    with pytest.raises(RegistrationError, match="unknown arch"):
        register(g)


def test_register_bad_mult_edge_rejected():
    g = TaskGraph("g", {"a": Task("a", (V(),)), "b": Task("b", (V(),))},
                  [("a", "b")])
    g.mult[("b", "v", "a")] = 2.0
    with pytest.raises(RegistrationError, match="no matching edge"):
        register(g)


def test_variant_accuracy_bounds():
    with pytest.raises(ValueError):
        Variant("v", "gemma-2b", accuracy=1.5)
    with pytest.raises(ValueError):
        Variant("v", "gemma-2b", accuracy=0.0)


def test_path_fractions_must_sum_to_one():
    with pytest.raises(ValueError, match="sum"):
        TaskGraph("g", {"a": Task("a", (V(),)), "b": Task("b", (V(),))},
                  [("a", "b")], path_fractions={("a", "b"): 0.5})
