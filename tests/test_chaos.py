"""Chaos engine tests (DESIGN.md §13): correlated failure domains, spot
preemption drains, closed-loop detection, mid-bin emergency re-planning,
the graceful-degradation ladder, and the seeded fuzzer + its pinned
SLO-breaking regression cases."""
import json
import math
import os

import pytest

from repro.chaos import DegradationLadder, EmergencyReplanner, FailureDetector
from repro.chaos.fuzz import (DEFAULT_THRESHOLD, FuzzCase, case_from_seed,
                              fuzz, run_case)
from repro.core.apps import get_app
from repro.core.controller import Controller
from repro.core.frontend import Frontend
from repro.core.milp import Planner
from repro.core.profiler import Profiler
from repro.hwspec import chaos_cluster, validate_domain_names
from repro.reconfig import TransitionPlanner
from repro.runtime import (ClusterRuntime, DomainFailureEvent, FailureEvent,
                           PreemptionEvent, Scenario, SimBackend)

KW = dict(max_tuples_per_task=32, bb_nodes=8, bb_time_s=3.0)
PINS = os.path.join(os.path.dirname(__file__), "chaos_pins.json")


@pytest.fixture(scope="module")
def fleet():
    cluster = chaos_cluster()
    graph = get_app("social_media")
    prof = Profiler(graph, cluster=cluster)
    planner = Planner(graph, prof, s_avail=cluster.total_units, **KW)
    return cluster, graph, prof, planner


@pytest.fixture(scope="module")
def cfg15(fleet):
    _, _, _, planner = fleet
    planner.dead_units = {}
    cfg = planner.plan(15.0)
    assert cfg is not None
    return cfg


@pytest.fixture(scope="module")
def cfg30(fleet):
    _, _, _, planner = fleet
    planner.dead_units = {}
    cfg = planner.plan(30.0)
    assert cfg is not None
    return cfg


def make_rt(fleet, cfg, seed=0, **kw):
    cluster, graph, _, _ = fleet
    return ClusterRuntime(graph, cfg, SimBackend(), seed=seed,
                          cluster=cluster, **kw)


# ---------------------------------------------------------------------------
# correlated failure domains
# ---------------------------------------------------------------------------
def test_domain_units_span_pools(fleet):
    cluster, *_ = fleet
    units = cluster.domain_units()
    # both pools are members of both rack domains (interleaved devices)
    assert units == {"r0": {"v5e": 4, "spot": 7},
                     "r1": {"v5e": 4, "spot": 7}}
    with pytest.raises(ValueError, match="unknown"):
        validate_domain_names(cluster, ["r9"], "test")


def test_domain_failure_records_blast_radius_across_pools(fleet, cfg15):
    """A domain failure takes its units in EVERY member pool — the spot
    pool's share is recorded as dead even though the plan deployed
    nothing there (the hardware is gone either way), and the deployed
    pool loses the servers packed on the domain's devices."""
    rt = make_rt(fleet, cfg15)
    before = len(rt.servers)
    sc = Scenario.poisson(15.0, duration_s=6.0, warmup_s=1.0).with_chaos(
        DomainFailureEvent(at_s=2.0, domain="r0"))
    m = rt.run(sc)
    dead = rt.dead_units()
    assert dead["v5e"] == 4          # the domain's v5e share
    assert dead["spot"] == 7         # physical radius, nothing deployed
    assert len(rt.servers) < before  # deployed victims actually died
    # post-failure outcome is filed under the domain's attainment ledger
    assert "r0" in m.by_domain and m.by_domain["r0"].total_requests > 0
    # drops caused by the kill are attributed to failed capacity
    assert m.drop_reasons.get("failed_capacity", 0) > 0


def test_domain_failure_requires_cluster(fleet, cfg15):
    _, graph, _, _ = fleet
    rt = ClusterRuntime(graph, cfg15, SimBackend(), seed=0)  # no cluster=
    sc = Scenario.poisson(15.0, duration_s=4.0).with_chaos(
        DomainFailureEvent(at_s=1.0, domain="r0"))
    with pytest.raises(RuntimeError, match="cluster"):
        rt.run(sc)


def test_domain_failure_spares_other_domain(fleet, cfg30):
    """Placement-aware blast radius: a plan spread over both racks loses
    only its r0 share — some servers must survive an r0 kill."""
    rt = make_rt(fleet, cfg30)
    sc = Scenario.poisson(30.0, duration_s=8.0, warmup_s=1.0).with_chaos(
        DomainFailureEvent(at_s=2.0, domain="r0"))
    m = rt.run(sc)
    assert len(rt.servers) > 0       # r1's servers survived
    # survivors keep serving after the failure
    assert m.by_domain["r0"].completions > 0


# ---------------------------------------------------------------------------
# spot preemption
# ---------------------------------------------------------------------------
def test_preemption_notice_drains(fleet, cfg15):
    """The notice window is a drain hand-over: in-flight and notice-
    window work completes, nothing new is served past the hand-over,
    and the reclaimed capacity is recorded at NOTICE time."""
    sc = Scenario.poisson(12.0, duration_s=6.0, warmup_s=0.0).with_chaos(
        PreemptionEvent(at_s=2.0, pool="v5e", notice_s=1.0))
    rt = make_rt(fleet, cfg15)
    m = rt.run(sc)
    # the whole pool is reclaimed: physical capacity recorded dead
    assert rt.dead_units()["v5e"] == 8
    # every preempted server carries the hand-over retire stamp
    assert all(s.retire_at <= 3.0 for s in rt.servers
               if s.tup.pool == "v5e")
    # work arriving before the hand-over was served...
    assert m.completions > 0
    # ...and arrivals after it can only drop, attributed to the loss
    assert m.drop_reasons.get("failed_capacity", 0) > 0


def test_preemption_notice_beyond_run_changes_nothing(fleet, cfg15):
    """A notice whose hand-over lands past the run horizon must leave
    the served workload bit-identical — draining streams serve normally
    until their retire time."""
    base = Scenario.poisson(12.0, duration_s=5.0, warmup_s=0.0)
    m0 = make_rt(fleet, cfg15).run(base)
    rt = make_rt(fleet, cfg15)
    m1 = rt.run(base.with_chaos(
        PreemptionEvent(at_s=1.0, pool="v5e", notice_s=60.0)))
    assert m1.completions == m0.completions
    assert m1.latencies_ms == m0.latencies_ms
    # ...but the doomed capacity is ALREADY recorded for the planner
    assert rt.dead_units()["v5e"] == 8


def test_partial_preemption_respects_fraction(fleet, cfg30):
    rt = make_rt(fleet, cfg30)
    sc = Scenario.poisson(20.0, duration_s=6.0, warmup_s=0.0).with_chaos(
        PreemptionEvent(at_s=1.0, pool="v5e", notice_s=0.5, fraction=0.25))
    rt.run(sc)
    assert rt.dead_units()["v5e"] == 2      # 25% of 8 physical units
    assert len(rt.servers) > 0              # the rest keeps serving


def test_unknown_pool_fails_loud(fleet, cfg15):
    rt = make_rt(fleet, cfg15)
    sc = Scenario.poisson(10.0, duration_s=3.0).with_chaos(
        PreemptionEvent(at_s=1.0, pool="nope"))
    with pytest.raises(ValueError, match="nope"):
        rt.run(sc)


# ---------------------------------------------------------------------------
# closed-loop detection
# ---------------------------------------------------------------------------
def test_detector_matches_manual_injection(fleet):
    """The detector's derived dead_units must equal what the operator
    would have hand-fed for the same failure, bin for bin."""
    cluster, graph, prof, _ = fleet
    det = FailureDetector()
    ctrl = Controller(graph, prof, s_avail=cluster.total_units,
                      planner_kwargs=dict(KW), detector=det)
    # bin 0: a pool-scoped failure kills half the classify streams
    sc = Scenario.poisson(15.0, duration_s=6.0, warmup_s=1.0).with_failures(
        FailureEvent(at_s=2.0, task="classify", count=2, pool="v5e"))
    ctrl.step(0, 15.0, scenario=sc, seed=0)
    derived = det.dead_units()
    assert derived == {"v5e": 1}     # 2 streams × (1 chip / 4 streams), ceil
    # bin 1: the planner consumes the DERIVED value automatically (the
    # demand jump re-triggers the plan)
    rep = ctrl.step(1, 25.0, sim_seconds=4.0, seed=1)
    assert rep.replanned
    assert ctrl.planner.dead_units == derived
    # a manual override that contradicts the observation fails loud
    # instead of silently preferring either
    with pytest.raises(ValueError, match="conflict"):
        ctrl.step(2, 25.0, sim_seconds=4.0, seed=2, dead_units={"v5e": 3})
    # the merge contract directly: agreement passes, extra pools union
    from repro.core.controller import _merge_dead_units
    assert _merge_dead_units(det, {"v5e": 1}) == {"v5e": 1}
    assert _merge_dead_units(det, {"spot": 2}) == {"v5e": 1, "spot": 2}
    assert _merge_dead_units(None, {"spot": 2}) == {"spot": 2}


def test_detector_accumulates_across_bins(fleet, cfg15):
    det = FailureDetector()
    for i in range(2):
        rt = make_rt(fleet, cfg15, seed=i)
        sc = Scenario.poisson(10.0, duration_s=4.0,
                              warmup_s=1.0).with_failures(
            FailureEvent(at_s=1.0, task="classify", count=2, pool="v5e"))
        rt.run(sc)
        det.observe(rt)
    assert det.dead_units() == {"v5e": 2}   # 1 unit (ceil'd) per bin
    det.forget("v5e")
    assert det.dead_units() == {}


# ---------------------------------------------------------------------------
# mid-bin emergency re-planning
# ---------------------------------------------------------------------------
def test_midbin_emergency_beats_detection_off(fleet, cfg30):
    """The acceptance bar: detector-driven mid-bin emergency re-planning
    must cut the post-failure (in-window) SLO violation rate at least
    3x against the detection-off baseline that waits for the end of the
    bin (ISSUE: chaos engine acceptance)."""
    cluster, graph, prof, _ = fleet
    storm = Scenario.poisson(30.0, duration_s=16.0,
                             warmup_s=1.0).with_chaos(
        DomainFailureEvent(at_s=3.0, domain="r0"))
    m_off = make_rt(fleet, cfg30).run(storm)
    epl = Planner(graph, prof, s_avail=cluster.total_units,
                  stickiness=0.05, **KW)
    mon = EmergencyReplanner(Frontend(graph), planner=epl,
                             reconfig=TransitionPlanner(cluster, graph),
                             planned_for_rps=30.0)
    m_on = make_rt(fleet, cfg30, monitor=mon).run(storm)
    off = m_off.by_domain["r0"].violation_rate
    on = m_on.by_domain["r0"].violation_rate
    assert mon.replans >= 1
    assert on * 3 <= off, f"mid-bin replan {on:.3f} vs off {off:.3f}"


def test_emergency_diffs_against_effective_config(fleet, cfg30):
    """After a kill the planned config counts capacity that no longer
    exists — the emergency path must diff against the LIVE deployment
    (a stale diff would try to drain dead streams and raise)."""
    rt = make_rt(fleet, cfg30)
    victims = [s.idx for s in rt.servers[:2]]
    rt.fail_instances(victims)
    eff = rt.effective_config()
    assert sum(eff.counts.values()) < sum(cfg30.counts.values())
    # dead capacity was attributed to the victims' pool
    assert rt.dead_units().get("v5e", 0) >= 1


# ---------------------------------------------------------------------------
# graceful-degradation ladder
# ---------------------------------------------------------------------------
def test_ladder_ordering(fleet, cfg15):
    """The shed order is admission → downshift → drop: level 1 refuses
    at the door without touching accuracy, level 2 downshifts variants,
    only level 3 drops at random — and a full queue is always refused
    BEFORE the random-drop coin is tossed."""
    _, graph, prof, _ = fleet
    ladder = DegradationLadder(profiler=prof)
    rt = make_rt(fleet, cfg15, ladder=ladder)
    entry = graph.entry

    ladder.escalate(rt, 0.0)
    assert ladder.level == 1
    assert not any(s.degraded for s in rt.servers)   # no downshift yet
    # a drained queue admits at level 1
    assert ladder.gate(rt, entry, 0.0) is None
    # an over-cap queue is refused at the door
    rt.queues[entry].extend(range(10_000))
    assert ladder.gate(rt, entry, 0.0) == "admission"
    rt.queues[entry].clear()

    ladder.escalate(rt, 0.0)
    assert ladder.level == 2
    degraded = [s for s in rt.servers if s.degraded]
    assert degraded, "level 2 must downshift profiled variants"
    orig = ladder._orig[degraded[0].idx]
    assert degraded[0].tup.accuracy <= orig.accuracy
    assert degraded[0].tup.latency_ms < orig.latency_ms

    ladder.escalate(rt, 0.0)
    assert ladder.level == 3
    # admission still wins over the random-drop coin on a full queue
    rt.queues[entry].extend(range(10_000))
    assert ladder.gate(rt, entry, 0.0) == "admission"
    rt.queues[entry].clear()
    # with headroom, level 3 sheds a fraction at random (seeded rng)
    verdicts = {ladder.gate(rt, entry, 0.0) for _ in range(200)}
    assert verdicts == {None, "shed"}

    # relaxing below level 2 restores the full-accuracy tuples
    ladder.relax(rt, 1.0)
    ladder.relax(rt, 1.0)
    assert ladder.level == 1
    assert not any(s.degraded for s in rt.servers)


def test_ladder_attainment_beats_hard_drops(fleet, cfg15):
    """The acceptance bar: under a surge the ladder must serve strictly
    more requests in-SLO than hard drops alone (ISSUE: chaos engine
    acceptance)."""
    _, graph, prof, _ = fleet
    surge = Scenario.poisson(60.0, duration_s=16.0, warmup_s=1.0)
    mon = EmergencyReplanner(Frontend(graph), planned_for_rps=15.0)
    m_hard = make_rt(fleet, cfg15, monitor=mon).run(surge)
    mon2 = EmergencyReplanner(Frontend(graph), planned_for_rps=15.0)
    ladder = DegradationLadder(profiler=prof)
    m_lad = make_rt(fleet, cfg15, monitor=mon2, ladder=ladder).run(surge)
    hard = m_hard.completions - m_hard.missed
    lad = m_lad.completions - m_lad.missed
    assert lad > hard, f"ladder {lad} vs hard drops {hard}"
    assert m_lad.degraded_served > 0         # downshift did the lifting
    # every shed decision is attributed
    assert set(m_lad.drop_reasons) <= {"deadline", "stale", "admission",
                                       "shed", "failed_capacity"}


def test_ladder_drop_attribution(fleet, cfg15):
    """Ladder decisions land in the degradation ledgers: admission drops
    under ``admission_dropped`` + ``drop_reasons``."""
    _, graph, prof, _ = fleet
    ladder = DegradationLadder(profiler=prof, min_queue_cap=0,
                               queue_cap_mult=0.0)
    ladder.level = 1        # cap forced to zero: refuse everything
    rt = make_rt(fleet, cfg15, ladder=ladder)
    m = rt.run(Scenario.poisson(10.0, duration_s=4.0, warmup_s=0.0))
    assert m.completions == 0
    assert m.admission_dropped == m.dropped > 0
    assert m.drop_reasons == {"admission": m.dropped}


def test_ladder_hysteresis_hold_downs(fleet, cfg15):
    """Hold-downs stop the one-rung-per-interval oscillation: a relax is
    refused until the level has held ``relax_hold_s`` since the last
    change in EITHER direction, and escalations respect their own hold
    and can jump multiple rungs."""
    _, _, prof, _ = fleet
    rt = make_rt(fleet, cfg15)
    ladder = DegradationLadder(profiler=prof, escalate_step=2,
                               escalate_hold_s=1.0, relax_hold_s=2.0)

    ladder.escalate(rt, 10.0)
    assert ladder.level == 2               # escalate_step rungs at once
    ladder.escalate(rt, 10.5)              # inside the escalate hold
    assert ladder.level == 2
    ladder.escalate(rt, 11.5)              # hold expired
    assert ladder.level == 3

    ladder.relax(rt, 12.0)                 # 0.5s since last change < 2s
    assert ladder.level == 3
    ladder.relax(rt, 13.6)                 # 2.1s after the escalation
    assert ladder.level == 2
    # a fresh escalation RESETS the relax clock
    ladder.escalate(rt, 14.0)
    assert ladder.level == 3
    ladder.relax(rt, 15.0)                 # only 1s since the escalation
    assert ladder.level == 3
    ladder.relax(rt, 16.1)
    assert ladder.level == 2
    ladder.reset()
    assert ladder.level == 0 and ladder._last_change_s == -math.inf


def test_level3_shed_is_deadline_aware(fleet, cfg15):
    """With request context, level 3 sheds exactly the arrivals whose
    predicted finish (queue drain + fastest remaining path) already
    misses the deadline — a generous deadline is admitted even at level
    3, a hopeless one is shed deterministically (no coin)."""
    from repro.core.dispatch import QueuedRequest

    _, graph, prof, _ = fleet
    # a huge admission cap keeps the level-1 rung out of the way so the
    # level-3 criterion is what decides
    ladder = DegradationLadder(profiler=prof, queue_cap_mult=100.0)
    rt = make_rt(fleet, cfg15, ladder=ladder)
    entry = graph.entry
    ladder.level = 3
    now = 5.0
    fastest_s = rt._fastest[entry] / 1e3
    assert fastest_s > 0

    generous = QueuedRequest(0, 0, entry, now, now + 1000.0)
    hopeless = QueuedRequest(1, 1, entry, now, now + fastest_s / 2)
    for _ in range(50):     # no randomness on either verdict
        assert ladder.gate(rt, entry, now, req=generous) is None
        assert ladder.gate(rt, entry, now, req=hopeless) == "shed"

    # a backlog pushes the predicted finish past an otherwise-makeable
    # deadline: queue drain time is part of the estimate
    makeable = QueuedRequest(2, 2, entry, now, now + fastest_s + 1.0)
    assert ladder.gate(rt, entry, now, req=makeable) is None
    rps = sum(s.tup.throughput / max(s.tup.streams, 1)
              for s in rt.by_task[entry])
    backlog = int(math.ceil(rps * 2.0))     # ~2s of queue drain > 1s slack
    rt.queues[entry].extend(
        QueuedRequest(10 + i, 10 + i, entry, now, now + 1000.0)
        for i in range(backlog))
    assert ladder.gate(rt, entry, now, req=makeable) == "shed"
    rt.queues[entry].clear()

    # a dead entry fleet sheds everything — nothing can be served
    for s in rt.by_task[entry]:
        s.retire_at = now - 1.0
    assert ladder.gate(rt, entry, now, req=generous) == "shed"


# ---------------------------------------------------------------------------
# fuzzer
# ---------------------------------------------------------------------------
def test_fuzzer_deterministic():
    a, b = case_from_seed(7), case_from_seed(7)
    assert a == b and a.case_id == b.case_id
    cases = [case_from_seed(s).case_id for s in range(6)]
    assert len(set(cases)) == len(cases)     # distinct scenarios
    r1 = run_case(case_from_seed(7))
    r2 = run_case(case_from_seed(7))
    assert r1.violation_rate == r2.violation_rate
    assert r1.completions == r2.completions


@pytest.mark.parametrize("fast", [True, False],
                         ids=["fast", "legacy"])
def test_fuzzer_pins_still_break(fast):
    """Regression pins: the fuzzer's recorded SLO-breaking scenarios
    must still break deterministically (>= 3 distinct cases) — on the
    vectorized event loop AND the legacy oracle (the full 23-pin
    fast-vs-legacy differential lives in tests/test_runtime_parity.py)."""
    with open(PINS) as f:
        pins = json.load(f)
    assert len(pins["cases"]) >= 3
    threshold = pins["threshold"]
    for cid, meta in sorted(pins["cases"].items())[:3]:
        case = case_from_seed(meta["seed"])
        assert case.case_id == cid, "pin drifted from its seed"
        res = run_case(case, threshold, fast=fast)
        assert res.breaking, (
            f"pinned case {cid} no longer breaks "
            f"(vrate={res.violation_rate:.3f} <= {threshold})")
