"""Sharding policy: divisibility safety for every assigned cell, spec
de-duplication, and segment-mesh construction (pure — no multi-device
runtime needed; specs are just metadata)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, applicable
from repro.sharding.policy import ShardingPolicy, make_policy
from repro.sharding.segments import SEGMENT_SHAPES, by_name, catalogue


class FakeMesh:
    """Mesh stand-in: policy only reads axis_names + shape."""
    def __init__(self, shape_by_name):
        self.axis_names = tuple(shape_by_name)
        self.shape = dict(shape_by_name)
        self.devices = np.empty(tuple(shape_by_name.values()),
                                dtype=object)


POD = FakeMesh({"data": 16, "model": 16})
MULTIPOD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def all_cells():
    for a in ARCHS.values():
        for s in SHAPES.values():
            if applicable(a, s):
                yield a, s


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
def test_every_cell_has_divisible_rules(mesh):
    for arch, shape in all_cells():
        pol = make_policy(arch, shape, mesh,
                          training=(shape.kind == "train"))
        for logical, axes in pol.rules.items():
            if axes is None:
                continue
            size = int(np.prod([mesh.shape[a] for a in axes]))
            dim = _logical_dim(arch, shape, logical)
            if dim is not None:
                assert dim % size == 0, (arch.name, shape.name, logical,
                                         dim, size)


def _logical_dim(arch, shape, logical):
    ssm = arch.ssm
    return {
        "batch": shape.global_batch,
        "qheads": arch.num_heads or None,
        "kvheads": arch.num_kv_heads or None,
        "seq": shape.seq_len,
        "cache_seq": shape.seq_len,
        "head_dim": arch.head_dim or None,
        "ff": arch.d_ff or None,
        "vocab": arch.vocab_size,
        "embed": arch.d_model,
        "experts": arch.moe.num_experts if arch.moe else None,
        "expert_ff": arch.moe.d_ff_expert if arch.moe else None,
        "expert_embed": arch.d_model if arch.moe else None,
        "ssm_heads": ssm.num_heads(arch.d_model) if ssm else None,
        "ssm_pdim": ssm.head_dim if ssm else None,
        "ssm_state": ssm.d_state if ssm else None,
        "layers": None,
    }.get(logical)


def test_spec_deduplicates_mesh_axes():
    pol = ShardingPolicy(mesh=POD, rules={"seq": ("model",),
                                          "ff": ("model",),
                                          "batch": ("data",)})
    spec = pol.spec("batch", "seq", "ff")
    assert spec == P("data", ("model",), None) or spec == P("data", "model",
                                                            None)


def test_null_policy_is_identity(null_policy):
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert null_policy.pin(x, "batch", "ff") is x
    assert null_policy.spec("batch") == P(None)


@pytest.mark.parametrize("mesh", [POD, MULTIPOD], ids=["pod", "multipod"])
def test_attention_mode_selection(mesh):
    qwen = ARCHS["qwen2-7b"]          # 28 heads % 16 != 0 → context
    deep = ARCHS["deepseek-67b"]      # 64 heads % 16 == 0 → head TP
    s = SHAPES["train_4k"]
    assert make_policy(qwen, s, mesh).attn_mode == "context"
    assert make_policy(deep, s, mesh).attn_mode == "head_tp"


def test_moe_expert_parallelism_over_data_axes():
    mav = ARCHS["llama4-maverick-400b-a17b"]
    pol = make_policy(mav, SHAPES["train_4k"], MULTIPOD, training=True)
    assert pol.rules["experts"] is not None
    assert set(pol.rules["experts"]).issubset({"pod", "data"})
    assert pol.rules["expert_ff"] == ("model",)


def test_big_dense_serving_gets_weight_storage_sharding():
    deep = ARCHS["deepseek-67b"]
    pol = make_policy(deep, SHAPES["decode_32k"], POD, training=False)
    assert pol.rules["embed"] is not None        # ZeRO-style streaming
    gem = ARCHS["gemma-2b"]
    pol2 = make_policy(gem, SHAPES["decode_32k"], POD, training=False)
    assert pol2.rules["embed"] is None           # small model: replicated


def test_segment_catalogue():
    segs = catalogue()
    assert len(segs) == 7 * 4
    assert all(s.chips == s.shape[0] * s.shape[1] for s in segs)
    assert by_name("4x4s2").chips == 16
    unopt = catalogue(spatial=False)
    assert len(unopt) == 1 and unopt[0].streams == 1


def test_segment_mesh_construction():
    from repro.launch.mesh import make_segment_mesh
    m = make_segment_mesh(1)
    assert dict(m.shape) == {"data": 1, "model": 1}
