"""jigsaw-lint (tools/analyze) coverage: every pass against known-bad
and known-good fixtures, the baseline add/stale/update workflow, the
layering exception machinery, the CLI, the self-run over src/repro, and
the dynamic determinism sanitizer (DESIGN.md §15)."""
import json
import os
import sys
import textwrap

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.analyze import AnalyzerConfig, load_config, run_passes  # noqa: E402
from tools.analyze.__main__ import main as analyze_main  # noqa: E402
from tools.analyze.baseline import (compare, load_baseline,  # noqa: E402
                                    save_baseline)
from tools.analyze.config import LayerException, _mini_toml  # noqa: E402
from tools.analyze.core import Project  # noqa: E402


# ----------------------------------------------------------------------
# fixture-project helpers
# ----------------------------------------------------------------------
def make_project(tmp_path, files):
    """Write ``{relpath: source}`` under ``tmp_path/pkg`` and parse it."""
    for rel, src in files.items():
        p = tmp_path / "pkg" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return Project("pkg", "pkg", repo_root=str(tmp_path))


def make_config(**kw):
    base = dict(
        root="pkg", package="pkg",
        layers={"core": [], "runtime": ["core"], "gw": ["runtime"],
                "obs": []},
        determinism_packages=["core", "runtime"],
        asyncio_packages=["gw"],
        failloud_packages=["core", "gw"])
    base.update(kw)
    return AnalyzerConfig(**base)


def keys(findings, pass_name=None):
    return [f.key for f in findings
            if pass_name is None or f.pass_name == pass_name]


# ----------------------------------------------------------------------
# determinism
# ----------------------------------------------------------------------
def test_determinism_flags_every_banned_source(tmp_path):
    proj = make_project(tmp_path, {"core/sim.py": """\
        import random
        import time
        import numpy as np
        from numpy.random import default_rng

        def step():
            t = time.time()
            time.sleep(0.1)
            x = np.random.rand()
            r = random.random()
            rng = default_rng()
            return t, x, r, rng
        """})
    found = run_passes(proj, make_config(), only=["determinism"])
    assert len(found) == 5
    msgs = " | ".join(f.message for f in found)
    assert "wall-clock" in msgs
    assert "real sleep" in msgs
    assert "unseeded" in msgs
    assert all(f.symbol == "step" for f in found)
    assert all(f.file == "pkg/core/sim.py" for f in found)


def test_determinism_clean_on_seeded_rng_and_monotonic(tmp_path):
    proj = make_project(tmp_path, {"core/sim.py": """\
        import time
        from numpy.random import default_rng

        def step(rng):
            t0 = time.monotonic()          # solver wall time: sanctioned
            noise = rng.normal()
            child = default_rng(1234)
            return t0, noise, child
        """})
    assert run_passes(proj, make_config(), only=["determinism"]) == []


def test_determinism_scope_and_inline_allow(tmp_path):
    src = """\
        import time

        def step():
            return time.time()
        """
    # same source outside the determinism scope: clean
    proj = make_project(tmp_path, {"obs/clock.py": src})
    assert run_passes(proj, make_config(), only=["determinism"]) == []
    # inside scope with a trailing allow: suppressed
    proj = make_project(tmp_path, {"core/clock.py": """\
        import time

        def step():
            return time.time()  # jigsaw: allow(determinism)
        """})
    assert run_passes(proj, make_config(), only=["determinism"]) == []


# ----------------------------------------------------------------------
# layering
# ----------------------------------------------------------------------
_LAYER_FILES = {
    "core/a.py": "X = 1\n",
    "runtime/b.py": "from pkg.core.a import X\n",     # allowed: runtime<-core
}


def test_layering_matrix_violation(tmp_path):
    files = dict(_LAYER_FILES)
    files["core/bad.py"] = "import pkg.runtime.b\n"   # core may not -> runtime
    proj = make_project(tmp_path, files)
    found = run_passes(proj, make_config(), only=["layering"])
    assert len(found) == 1
    assert found[0].file == "pkg/core/bad.py"
    assert "crosses the layer matrix" in found[0].message


def test_layering_named_exception_and_staleness(tmp_path):
    files = dict(_LAYER_FILES)
    files["core/bad.py"] = "import pkg.runtime.b\n"
    exc = LayerException("core/bad.py", "runtime", "test shim")
    cfg = make_config(exceptions=[exc])
    # exception sanctions the crossing
    proj = make_project(tmp_path, files)
    assert run_passes(proj, cfg, only=["layering"]) == []
    # import removed -> the exception is stale and FAILS the run
    files["core/bad.py"] = "Y = 2\n"
    proj = make_project(tmp_path, files)
    found = run_passes(proj, cfg, only=["layering"])
    assert len(found) == 1
    assert found[0].symbol == "<stale-exception>"
    assert "stale" in found[0].message


def test_layering_lazy_grant_is_function_level_only(tmp_path):
    lazy_src = """\
        def bind():
            from pkg.runtime.b import X
            return X
        """
    cfg = make_config(lazy={"core": ["runtime"]})
    files = dict(_LAYER_FILES)
    files["core/lazyimp.py"] = lazy_src
    assert run_passes(make_project(tmp_path, files), cfg,
                      only=["layering"]) == []
    # the same dependency at module level is NOT covered by [lazy]
    files["core/lazyimp.py"] = "from pkg.runtime.b import X\n"
    found = run_passes(make_project(tmp_path, files), cfg,
                       only=["layering"])
    assert len(found) == 1 and "crosses the layer matrix" in found[0].message


def test_layering_type_checking_imports_ignored(tmp_path):
    files = dict(_LAYER_FILES)
    files["core/typed.py"] = """\
        from typing import TYPE_CHECKING
        if TYPE_CHECKING:
            from pkg.runtime.b import X
        """
    assert run_passes(make_project(tmp_path, files), make_config(),
                      only=["layering"]) == []


def test_layering_module_cycle_detected(tmp_path):
    proj = make_project(tmp_path, {
        "core/x.py": "import pkg.core.y\n",
        "core/y.py": "import pkg.core.x\n",
    })
    found = run_passes(proj, make_config(), only=["layering"])
    assert len(found) == 1
    assert found[0].symbol == "<cycle>"
    assert "pkg.core.x" in found[0].message
    assert "pkg.core.y" in found[0].message
    # lazy imports cannot deadlock the import system: no cycle
    proj = make_project(tmp_path, {
        "core/x.py": "import pkg.core.y\n",
        "core/y.py": "def f():\n    import pkg.core.x\n",
    })
    assert run_passes(proj, make_config(), only=["layering"]) == []


# ----------------------------------------------------------------------
# asyncio_race
# ----------------------------------------------------------------------
def test_asyncio_rmw_across_await_flagged(tmp_path):
    proj = make_project(tmp_path, {"gw/g.py": """\
        class G:
            async def bump(self):
                v = self.count
                await self.flush()
                self.count = v + 1
        """})
    found = run_passes(proj, make_config(), only=["asyncio_race"])
    assert len(found) == 1
    assert "self.count" in found[0].message
    assert found[0].symbol == "G.bump"


def test_asyncio_rmw_under_lock_clean(tmp_path):
    proj = make_project(tmp_path, {"gw/g.py": """\
        class G:
            async def bump(self):
                async with self._lock:
                    v = self.count
                    await self.flush()
                    self.count = v + 1
        """})
    assert run_passes(proj, make_config(), only=["asyncio_race"]) == []


def test_asyncio_cross_iteration_rmw_flagged(tmp_path):
    # read in iteration N, await, write in iteration N+1 — only visible
    # because the loop body is replayed twice
    proj = make_project(tmp_path, {"gw/g.py": """\
        class G:
            async def drain(self, items):
                for it in items:
                    self.pending = self.pending - 1
                    await self.push(it)
        """})
    found = run_passes(proj, make_config(), only=["asyncio_race"])
    assert len(found) == 1 and "self.pending" in found[0].message


def test_asyncio_lock_as_argument_clean(tmp_path):
    # the lock arrives as an annotated parameter: the bare name 'guard'
    # says nothing, the annotation marks it as a mutual exclusion
    proj = make_project(tmp_path, {"gw/g.py": """\
        import asyncio

        class G:
            async def bump(self, guard: asyncio.Lock):
                async with guard:
                    v = self.count
                    await self.flush()
                    self.count = v + 1
        """})
    assert run_passes(proj, make_config(), only=["asyncio_race"]) == []


def test_asyncio_lock_bound_local_clean(tmp_path):
    # a local bound from a lock-ish attribute counts as a lock too
    proj = make_project(tmp_path, {"gw/g.py": """\
        class G:
            async def bump(self):
                guard = self._mutex
                async with guard:
                    v = self.count
                    await self.flush()
                    self.count = v + 1
        """})
    assert run_passes(proj, make_config(), only=["asyncio_race"]) == []


def test_asyncio_non_lock_name_still_flagged(tmp_path):
    # an unannotated, un-lock-ish context manager must NOT suppress:
    # dataflow only trusts provably lock-bound names
    proj = make_project(tmp_path, {"gw/g.py": """\
        class G:
            async def bump(self, guard):
                async with guard:
                    v = self.count
                    await self.flush()
                    self.count = v + 1
        """})
    found = run_passes(proj, make_config(), only=["asyncio_race"])
    assert len(found) == 1 and "self.count" in found[0].message


def test_asyncio_blocking_calls_flagged(tmp_path):
    proj = make_project(tmp_path, {"gw/g.py": """\
        import time

        class G:
            async def poll(self):
                time.sleep(0.5)
                with open("state.json") as f:
                    return f.read()
        """})
    found = run_passes(proj, make_config(), only=["asyncio_race"])
    assert sorted("time.sleep" in f.message or "open" in f.message
                  for f in found) == [True, True]
    # asyncio.sleep is the non-blocking counterpart: clean
    proj = make_project(tmp_path, {"gw/g.py": """\
        import asyncio

        class G:
            async def poll(self):
                await asyncio.sleep(0.5)
        """})
    assert run_passes(proj, make_config(), only=["asyncio_race"]) == []


# ----------------------------------------------------------------------
# failloud
# ----------------------------------------------------------------------
def test_failloud_flags_bare_and_silent_broad(tmp_path):
    proj = make_project(tmp_path, {"core/h.py": """\
        def bare(risky):
            try:
                risky()
            except:
                pass

        def silent(risky):
            try:
                risky()
            except Exception:
                pass

        def counted(risky, errs):
            try:
                risky()
            except Exception as e:
                errs.append(e)

        def narrow(risky):
            try:
                risky()
            except ValueError:
                pass
        """})
    found = run_passes(proj, make_config(), only=["failloud"])
    assert sorted(f.symbol for f in found) == ["bare", "silent"]
    assert any("bare `except:`" in f.message for f in found)
    assert any("silent body" in f.message for f in found)


# ----------------------------------------------------------------------
# units
# ----------------------------------------------------------------------
def test_units_flags_mixed_suffix_arithmetic(tmp_path):
    proj = make_project(tmp_path, {"core/u.py": """\
        def f(wait_ms, deadline_s, size_bytes, size_mb):
            bad_sub = deadline_s - wait_ms
            bad_cmp = wait_ms > deadline_s
            bad_size = size_bytes + size_mb
            return bad_sub, bad_cmp, bad_size
        """})
    found = run_passes(proj, make_config(), only=["units"])
    assert len(found) == 3
    assert all("mixes units" in f.message for f in found)


def test_units_conversion_constant_erases_unit(tmp_path):
    proj = make_project(tmp_path, {"core/u.py": """\
        def f(wait_ms, deadline_s, budget_s, size_bytes):
            ok_conv = deadline_s - wait_ms * 1e-3
            ok_same = deadline_s + budget_s
            ok_plain = deadline_s + 3.0
            ok_ratio = size_bytes / budget_s
            ok_shift = size_bytes / (1 << 20)
            return ok_conv, ok_same, ok_plain, ok_ratio, ok_shift
        """})
    assert run_passes(proj, make_config(), only=["units"]) == []


# ----------------------------------------------------------------------
# baseline workflow
# ----------------------------------------------------------------------
def test_baseline_add_stale_update_roundtrip(tmp_path):
    proj = make_project(tmp_path, {"core/sim.py": """\
        import time

        def a():
            return time.time()

        def b():
            time.sleep(1)
        """})
    found = run_passes(proj, make_config(), only=["determinism"])
    assert len(found) == 2

    # 1) nothing pinned: everything is NEW -> failed
    res = compare(found, {})
    assert len(res.new) == 2 and res.failed

    # 2) pin, reload, re-compare: everything BASELINED -> passing
    path = str(tmp_path / "bl.json")
    save_baseline(found, path)
    pinned = load_baseline(path)
    assert set(pinned) == set(keys(found))
    res = compare(found, pinned)
    assert res.new == [] and res.stale == [] and not res.failed

    # 3) fix one violation: its pin is STALE -> failed again
    proj = make_project(tmp_path, {"core/sim.py": """\
        import time

        def a():
            return time.time()
        """})
    found2 = run_passes(proj, make_config(), only=["determinism"])
    res = compare(found2, pinned)
    assert res.new == [] and len(res.stale) == 1 and res.failed

    # 4) missing file -> empty baseline; malformed file -> loud error
    assert load_baseline(str(tmp_path / "missing.json")) == {}
    bad = tmp_path / "bad.json"
    bad.write_text('{"wrong": 1}')
    with pytest.raises(ValueError):
        load_baseline(str(bad))


# ----------------------------------------------------------------------
# CLI end-to-end (exercises the mini-TOML config loader on 3.10)
# ----------------------------------------------------------------------
_CLI_TOML = """\
[analyze]
root = "pkg"
package = "pkg"

[layers]
core = []

[determinism]
packages = ["core"]

[failloud]
packages = ["core"]
"""


def test_cli_baseline_lifecycle(tmp_path, monkeypatch, capsys):
    (tmp_path / "layers.toml").write_text(_CLI_TOML)
    pkg = tmp_path / "pkg" / "core"
    pkg.mkdir(parents=True)
    (pkg / "sim.py").write_text(
        "import time\n\n\ndef step():\n    return time.time()\n")
    monkeypatch.chdir(tmp_path)
    argv = ["--config", "layers.toml", "--baseline", "bl.json"]

    # new finding -> exit 1, reported as NEW, JSON artifact written
    assert analyze_main(argv + ["--json", "out.json"]) == 1
    assert "NEW" in capsys.readouterr().out
    payload = json.loads((tmp_path / "out.json").read_text())
    assert len(payload["new"]) == 1
    assert payload["new"][0]["pass_name"] == "determinism"

    # pin it -> passing, reported as BASELINED
    assert analyze_main(argv + ["--update-baseline"]) == 0
    assert analyze_main(argv) == 0
    assert "BASELINED" in capsys.readouterr().out

    # fix the violation -> the leftover pin is stale -> exit 1
    (pkg / "sim.py").write_text("def step():\n    return 0.0\n")
    assert analyze_main(argv) == 1
    assert "STALE" in capsys.readouterr().out

    # re-pin (now empty) -> clean
    assert analyze_main(argv + ["--update-baseline"]) == 0
    assert analyze_main(argv) == 0
    assert json.loads((tmp_path / "bl.json").read_text())["entries"] == {}


def test_cli_unknown_pass_fails_loud(tmp_path, monkeypatch):
    (tmp_path / "layers.toml").write_text(_CLI_TOML)
    (tmp_path / "pkg").mkdir()
    monkeypatch.chdir(tmp_path)
    with pytest.raises(KeyError):
        analyze_main(["--config", "layers.toml", "--passes", "nope"])


def test_mini_toml_parser():
    data = _mini_toml(textwrap.dedent("""\
        # comment
        [analyze]
        root = "src/repro"   # trailing comment
        n = 3
        frac = 0.5
        flag = true

        [layers]
        gateway = ["core", "obs",
                   "runtime"]
        obs = []

        [[exception]]
        file = "core/x.py"
        package = "runtime"
        """))
    assert data["analyze"] == {"root": "src/repro", "n": 3, "frac": 0.5,
                               "flag": True}
    assert data["layers"]["gateway"] == ["core", "obs", "runtime"]
    assert data["layers"]["obs"] == []
    assert data["exception"] == [{"file": "core/x.py",
                                  "package": "runtime"}]


# ----------------------------------------------------------------------
# the real repo: config sanity + self-run must be clean vs baseline
# ----------------------------------------------------------------------
def test_repo_config_loads():
    cfg = load_config()
    assert cfg.root == "src/repro" and cfg.package == "repro"
    # every scoped package must be declared in the matrix
    scoped = (cfg.determinism_packages + cfg.asyncio_packages +
              cfg.failloud_packages)
    missing = [p for p in scoped if p not in cfg.layers]
    assert missing == []
    # the PR 2 core->runtime shims stay named, not blanket-waived
    assert any(e.file == "core/controller.py" and e.package == "runtime"
               for e in cfg.exceptions)


def test_self_run_over_src_repro_is_clean():
    cfg = load_config()
    proj = Project(cfg.root, cfg.package, repo_root=REPO)
    assert len(proj.files) > 50          # the real tree, not a stub dir
    res = compare(run_passes(proj, cfg), load_baseline())
    assert res.stale == [], f"stale baseline pins: {res.stale}"
    assert res.new == [], "new findings:\n" + "\n".join(
        f.render() for f in res.new)


# ----------------------------------------------------------------------
# dynamic determinism sanitizer
# ----------------------------------------------------------------------
def test_sanitizer_passes_clean_and_catches_wall_clock():
    from tools.analyze import sanitize_determinism as san
    # two seeded replays must be bit-identical ...
    assert san.main(["--seed", "3"]) == 0
    # ... and injected wall-clock jitter in service times must FAIL
    assert san.main(["--seed", "3", "--perturb"]) == 1
