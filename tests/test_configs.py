"""Assigned-architecture configs: exact hyper-parameters + applicability."""
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, applicable, get_arch

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
    "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
}


def test_all_ten_archs_present():
    assert set(ARCHS) == set(EXPECTED)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_hparams(name):
    a = ARCHS[name]
    L, d, H, KV, ff, V = EXPECTED[name]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads,
            a.d_ff, a.vocab_size) == (L, d, H, KV, ff, V)


def test_moe_configs():
    scout = ARCHS["llama4-scout-17b-a16e"]
    mav = ARCHS["llama4-maverick-400b-a17b"]
    assert scout.moe.num_experts == 16 and scout.moe.experts_per_token == 1
    assert mav.moe.num_experts == 128 and mav.moe.experts_per_token == 1


def test_ssm_state_sizes():
    assert ARCHS["mamba2-130m"].ssm.d_state == 128
    assert ARCHS["zamba2-7b"].ssm.d_state == 64


def test_param_counts_plausible():
    # name → (lo, hi) in billions of TOTAL params
    bounds = {"deepseek-67b": (60, 75), "gemma-2b": (2, 3.2),
              "granite-3-2b": (2, 3.6), "qwen2-7b": (6.5, 8.5),
              "pixtral-12b": (11, 14), "mamba2-130m": (0.1, 0.2),
              "zamba2-7b": (6, 9), "musicgen-large": (1.5, 3.5),
              "llama4-scout-17b-a16e": (90, 120),   # 109B total / 17B active
              "llama4-maverick-400b-a17b": (200, 440)}
    for name, (lo, hi) in bounds.items():
        total, active = ARCHS[name].param_count()
        assert lo <= total / 1e9 <= hi, (name, total / 1e9)
        assert active <= total


def test_moe_active_params():
    mav = ARCHS["llama4-maverick-400b-a17b"]
    total, active = mav.param_count()
    assert active < 0.15 * total  # 17B active of ~400B


def test_forty_cells_and_long_context_rule():
    cells = list(all_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8  # 8 full-attention archs skip long_500k
    assert all(s.name == "long_500k" for (_, s, _, _) in skipped)
    assert all("sub-quadratic" in r for (_, _, _, r) in skipped)
    subq = {a.name for (a, s, ok, _) in runnable if s.name == "long_500k"}
    assert subq == {"mamba2-130m", "zamba2-7b"}


def test_reduced_configs_are_small():
    for a in ARCHS.values():
        r = a.reduced()
        total, _ = r.param_count()
        assert total < 5e6, (a.name, total)
        assert r.family == a.family


def test_get_arch_reduced_suffix():
    assert get_arch("qwen2-7b-reduced").d_model == 64
    with pytest.raises(KeyError):
        get_arch("nonexistent")
