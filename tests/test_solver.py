"""Solver correctness: simplex vs vertex enumeration; B&B vs brute force
(hypothesis property tests — assignment requirement).  Plus the
bounded-variable revised-simplex specifics: implicit bounds vs reference,
degenerate/cycling instances, and warm-start == cold-start optimality."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.solver.branch_bound import solve_milp
from repro.core.solver.simplex import BoundedSimplex, solve_lp


def brute_force_lp(c, A, b):
    """Optimal vertex of {Ax<=b, x>=0} by enumeration (small dims)."""
    m, n = A.shape
    Afull = np.vstack([A, -np.eye(n)])
    bfull = np.concatenate([b, np.zeros(n)])
    best = np.inf
    for rows in itertools.combinations(range(m + n), n):
        Asub, bsub = Afull[list(rows)], bfull[list(rows)]
        if abs(np.linalg.det(Asub)) < 1e-9:
            continue
        x = np.linalg.solve(Asub, bsub)
        if (Afull @ x <= bfull + 1e-7).all():
            best = min(best, float(c @ x))
    return best


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000))
def test_simplex_matches_vertex_enumeration(seed):
    rng = np.random.default_rng(seed)
    n, m = 3, 5
    A = rng.normal(size=(m, n))
    b = rng.uniform(0.5, 2.0, size=m)       # x=0 feasible
    c = rng.normal(size=n)
    res = solve_lp(c, A_ub=A, b_ub=b)
    assert res.status in ("optimal", "unbounded")
    if res.status == "optimal":
        best = brute_force_lp(c, A, b)
        assert abs(res.objective - best) < 1e-5
        assert (A @ res.x <= b + 1e-6).all()
        assert (res.x >= -1e-9).all()


def test_simplex_equality_and_bounds():
    res = solve_lp(np.array([1.0, 2.0, 3.0]),
                   A_eq=np.array([[1.0, 1.0, 1.0]]), b_eq=np.array([1.0]),
                   ub=np.array([0.5, np.inf, np.inf]))
    assert res.status == "optimal"
    np.testing.assert_allclose(res.x, [0.5, 0.5, 0.0], atol=1e-8)


def test_simplex_infeasible_detected():
    res = solve_lp(np.array([1.0]), A_ub=np.array([[1.0], [-1.0]]),
                   b_ub=np.array([1.0, -2.0]))
    assert res.status == "infeasible"


def test_simplex_unbounded_detected():
    res = solve_lp(np.array([-1.0]), A_ub=np.array([[-1.0]]),
                   b_ub=np.array([0.0]))
    assert res.status == "unbounded"


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_bb_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    n, m = 4, 4
    A = rng.uniform(0, 1, size=(m, n))
    b = rng.uniform(1, 4, size=m)
    c = rng.normal(size=n)
    ub = np.full(n, 4.0)
    res = solve_milp(c, A, b, None, None, ub, np.ones(n, bool),
                     max_nodes=3000, time_limit_s=30.0)
    best = np.inf
    for x in itertools.product(range(5), repeat=n):
        xa = np.array(x, float)
        if (A @ xa <= b + 1e-9).all():
            best = min(best, float(c @ xa))
    assert res.status in ("optimal", "feasible")
    assert abs(res.objective - best) < 1e-6


def test_bb_respects_integrality_and_constraints():
    rng = np.random.default_rng(7)
    A = rng.uniform(0, 1, (6, 6))
    b = rng.uniform(2, 5, 6)
    c = rng.normal(size=6)
    ub = np.full(6, 10.0)
    res = solve_milp(c, A, b, None, None, ub, np.ones(6, bool),
                     max_nodes=500)
    if res.x is not None:
        assert np.abs(res.x - np.round(res.x)).max() < 1e-6
        assert (A @ res.x <= b + 1e-6).all()


def test_bb_mixed_integer():
    """One continuous + one integer variable."""
    # max x0 + x1 st x0 <= 1.5 (cont), x1 <= 2.5 (int) → 1.5 + 2
    c = np.array([-1.0, -1.0])
    A = np.array([[1.0, 0.0], [0.0, 1.0]])
    b = np.array([1.5, 2.5])
    res = solve_milp(c, A, b, None, None, np.array([np.inf, np.inf]),
                     np.array([False, True]), max_nodes=50)
    assert res.status in ("optimal", "feasible")
    assert abs(res.objective - (-3.5)) < 1e-6


# ---------------------------------------------------------------------------
# bounded-variable revised simplex
# ---------------------------------------------------------------------------
def brute_force_bounded_lp(c, A, b, lo, hi):
    """Optimal vertex of {Ax<=b, lo<=x<=hi} by enumeration (small dims)."""
    m, n = A.shape
    Afull = np.vstack([A, -np.eye(n), np.eye(n)])
    bfull = np.concatenate([b, -lo, hi])
    rows_all = [i for i in range(Afull.shape[0]) if np.isfinite(bfull[i])]
    best = np.inf
    for rows in itertools.combinations(rows_all, n):
        Asub, bsub = Afull[list(rows)], bfull[list(rows)]
        if abs(np.linalg.det(Asub)) < 1e-9:
            continue
        x = np.linalg.solve(Asub, bsub)
        if (Afull[rows_all] @ x <= bfull[rows_all] + 1e-7).all():
            best = min(best, float(c @ x))
    return best


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000))
def test_bounded_lp_matches_vertex_enumeration(seed):
    """lo/hi handled implicitly in the ratio test == bounds-as-rows."""
    rng = np.random.default_rng(seed)
    n, m = 4, 4
    A = rng.normal(size=(m, n))
    b = rng.uniform(0.5, 2.0, size=m)
    c = rng.normal(size=n)
    lo = rng.uniform(0.0, 0.3, n)
    hi = lo + rng.uniform(0.2, 2.0, n)
    res = solve_lp(c, A_ub=A, b_ub=b, lo=lo, ub=hi)
    best = brute_force_bounded_lp(c, A, b, lo, hi)
    if res.status == "optimal":
        assert abs(res.objective - best) < 1e-5
        assert (A @ res.x <= b + 1e-6).all()
        assert (res.x >= lo - 1e-8).all() and (res.x <= hi + 1e-8).all()
    else:
        assert not np.isfinite(best)


def test_beale_cycling_instance_terminates_optimal():
    """Beale's classic cycling LP: Dantzig pricing cycles without an
    anti-cycling rule; the Bland fallback must terminate at -1/20."""
    c = np.array([-0.75, 150.0, -0.02, 6.0])
    A = np.array([[0.25, -60.0, -1.0 / 25.0, 9.0],
                  [0.5, -90.0, -1.0 / 50.0, 3.0],
                  [0.0, 0.0, 1.0, 0.0]])
    b = np.array([0.0, 0.0, 1.0])
    res = solve_lp(c, A_ub=A, b_ub=b)
    assert res.status == "optimal"
    assert abs(res.objective - (-0.05)) < 1e-8


def test_degenerate_redundant_rows():
    """Many coincident constraints through the optimum (degenerate
    vertices) must not stall or mis-converge."""
    c = np.array([-1.0, -1.0])
    A = np.vstack([[1.0, 1.0]] * 6 + [[1.0, 0.0], [0.0, 1.0]])
    b = np.array([1.0] * 6 + [1.0, 1.0])
    res = solve_lp(c, A_ub=A, b_ub=b)
    assert res.status == "optimal"
    assert abs(res.objective - (-1.0)) < 1e-8


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_warm_start_equals_cold_start_after_bound_tightening(seed):
    """A child LP re-solved from the parent basis (dual simplex) must be
    exactly as optimal as a from-scratch solve — the B&B invariant."""
    rng = np.random.default_rng(seed)
    n, m = 6, 5
    A = rng.uniform(-0.5, 1.0, size=(m, n))
    b = rng.uniform(1.0, 4.0, size=m)
    c = rng.normal(size=n)
    hi = rng.uniform(1.0, 5.0, n)
    lo = np.zeros(n)
    solver = BoundedSimplex(c, A, b)
    parent = solver.solve(lo, hi)
    if parent.status != "optimal":
        return
    j = int(rng.integers(0, n))
    if rng.random() < 0.5:
        hi2, lo2 = hi.copy(), lo
        hi2[j] = np.floor(parent.x[j])
    else:
        lo2, hi2 = lo.copy(), hi
        lo2[j] = np.ceil(parent.x[j])
    if lo2[j] > hi2[j]:
        return
    warm = solver.solve(lo2, hi2, warm=parent.basis)
    cold = BoundedSimplex(c, A, b).solve(lo2, hi2)
    assert warm.status == cold.status
    if warm.status == "optimal":
        assert abs(warm.objective - cold.objective) \
            <= 1e-9 * (1.0 + abs(cold.objective))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_warm_start_equals_cold_start_after_rhs_change(seed):
    """Re-planning at a new demand = same matrix, new rhs: the previous
    basis stays dual feasible and the warm solve must match cold."""
    rng = np.random.default_rng(seed)
    n, m = 8, 6
    A = rng.uniform(-0.2, 1.0, size=(m, n))
    b = rng.uniform(1.0, 4.0, size=m)
    c = rng.normal(size=n)
    hi = rng.uniform(1.0, 5.0, n)
    solver = BoundedSimplex(c, A, b)
    r0 = solver.solve(np.zeros(n), hi)
    if r0.status != "optimal":
        return
    b2 = b * rng.uniform(0.9, 1.1, m)
    warm = solver.solve(np.zeros(n), hi, b=b2, warm=r0.basis)
    cold = BoundedSimplex(c, A, b2).solve(np.zeros(n), hi)
    assert warm.status == cold.status
    if warm.status == "optimal":
        assert abs(warm.objective - cold.objective) \
            <= 1e-9 * (1.0 + abs(cold.objective))


def test_warm_solve_counters():
    c = np.array([-1.0, -2.0, 1.0])
    A = np.array([[1.0, 1.0, 0.0], [0.0, 1.0, 1.0]])
    b = np.array([2.0, 3.0])
    s = BoundedSimplex(c, A, b)
    r0 = s.solve(np.zeros(3), np.full(3, 4.0))
    assert r0.status == "optimal" and not r0.warm_used
    hi2 = np.array([4.0, 1.0, 4.0])
    r1 = s.solve(np.zeros(3), hi2, warm=r0.basis)
    assert r1.status == "optimal" and r1.warm_used
    assert s.stats.warm_solves == 1 and s.stats.cold_solves == 1


def test_milp_reports_true_best_bound_on_node_cap():
    """When the search stops on the node cap, gap/best_bound must come from
    the heap minimum — not from the last popped node."""
    rng = np.random.default_rng(3)
    n, m = 8, 6
    A = rng.uniform(0.1, 1.0, size=(m, n))
    b = rng.uniform(2.0, 4.0, size=m)
    c = -rng.uniform(0.5, 1.5, size=n)
    ub = np.full(n, 6.0)
    res = solve_milp(c, A, b, None, None, ub, np.ones(n, bool),
                     max_nodes=3, time_limit_s=30.0)
    if res.x is not None:
        # bound is a valid lower bound on the (unknown) optimum, hence also
        # on the incumbent, and the gap is consistent with it
        assert res.best_bound <= res.objective + 1e-9
        assert res.gap == pytest.approx(
            max(0.0, res.objective - res.best_bound)
            / (abs(res.objective) + 1.0))


def test_milp_warm_node_lps_counted():
    rng = np.random.default_rng(11)
    n, m = 6, 5
    A = rng.uniform(0, 1, size=(m, n))
    b = rng.uniform(1, 4, size=m)
    c = rng.normal(size=n)
    res = solve_milp(c, A, b, None, None, np.full(n, 4.0),
                     np.ones(n, bool), max_nodes=500)
    assert res.lp_cold >= 1          # the root
    if res.nodes > 1:
        assert res.lp_warm >= 1      # children reuse the parent basis


def test_per_solve_objective_warm_equals_cold():
    """A cached BoundedSimplex must serve a family of solves whose
    OBJECTIVE drifts (the planner's stickiness penalty follows the
    incumbent): solving under ``c2`` with a warm basis taken under
    ``c1`` must equal a cold solve built for ``c2`` — the warm path
    restores dual feasibility against the new objective."""
    rng = np.random.default_rng(7)
    n, m = 5, 4
    A = rng.normal(size=(m, n))
    b = rng.uniform(1.0, 3.0, size=m)
    c1 = rng.normal(size=n)
    c2 = c1 + rng.normal(scale=2.0, size=n)   # substantial drift
    lo, hi = np.zeros(n), np.full(n, 5.0)

    solver = BoundedSimplex(c1, A_ub=A, b_ub=b)
    r1 = solver.solve(lo, hi)
    assert r1.status == "optimal"
    # warm re-solve under the NEW objective on the SAME cached matrix
    r2 = solver.solve(lo, hi, c=c2, warm=r1.basis)
    cold = BoundedSimplex(c2, A_ub=A, b_ub=b).solve(lo, hi)
    assert r2.status == cold.status == "optimal"
    assert abs(r2.objective - cold.objective) < 1e-8
    assert float(c2 @ r2.x) == pytest.approx(r2.objective)
    # and the original objective is NOT leaked back into later solves
    r3 = solver.solve(lo, hi)
    assert abs(r3.objective - cold.objective) < 1e-8
