"""Solver correctness: simplex vs vertex enumeration; B&B vs brute force
(hypothesis property tests — assignment requirement)."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.solver.branch_bound import solve_milp
from repro.core.solver.simplex import solve_lp


def brute_force_lp(c, A, b):
    """Optimal vertex of {Ax<=b, x>=0} by enumeration (small dims)."""
    m, n = A.shape
    Afull = np.vstack([A, -np.eye(n)])
    bfull = np.concatenate([b, np.zeros(n)])
    best = np.inf
    for rows in itertools.combinations(range(m + n), n):
        Asub, bsub = Afull[list(rows)], bfull[list(rows)]
        if abs(np.linalg.det(Asub)) < 1e-9:
            continue
        x = np.linalg.solve(Asub, bsub)
        if (Afull @ x <= bfull + 1e-7).all():
            best = min(best, float(c @ x))
    return best


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000))
def test_simplex_matches_vertex_enumeration(seed):
    rng = np.random.default_rng(seed)
    n, m = 3, 5
    A = rng.normal(size=(m, n))
    b = rng.uniform(0.5, 2.0, size=m)       # x=0 feasible
    c = rng.normal(size=n)
    res = solve_lp(c, A_ub=A, b_ub=b)
    assert res.status in ("optimal", "unbounded")
    if res.status == "optimal":
        best = brute_force_lp(c, A, b)
        assert abs(res.objective - best) < 1e-5
        assert (A @ res.x <= b + 1e-6).all()
        assert (res.x >= -1e-9).all()


def test_simplex_equality_and_bounds():
    res = solve_lp(np.array([1.0, 2.0, 3.0]),
                   A_eq=np.array([[1.0, 1.0, 1.0]]), b_eq=np.array([1.0]),
                   ub=np.array([0.5, np.inf, np.inf]))
    assert res.status == "optimal"
    np.testing.assert_allclose(res.x, [0.5, 0.5, 0.0], atol=1e-8)


def test_simplex_infeasible_detected():
    res = solve_lp(np.array([1.0]), A_ub=np.array([[1.0], [-1.0]]),
                   b_ub=np.array([1.0, -2.0]))
    assert res.status == "infeasible"


def test_simplex_unbounded_detected():
    res = solve_lp(np.array([-1.0]), A_ub=np.array([[-1.0]]),
                   b_ub=np.array([0.0]))
    assert res.status == "unbounded"


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_bb_matches_brute_force(seed):
    rng = np.random.default_rng(seed)
    n, m = 4, 4
    A = rng.uniform(0, 1, size=(m, n))
    b = rng.uniform(1, 4, size=m)
    c = rng.normal(size=n)
    ub = np.full(n, 4.0)
    res = solve_milp(c, A, b, None, None, ub, np.ones(n, bool),
                     max_nodes=3000, time_limit_s=30.0)
    best = np.inf
    for x in itertools.product(range(5), repeat=n):
        xa = np.array(x, float)
        if (A @ xa <= b + 1e-9).all():
            best = min(best, float(c @ xa))
    assert res.status in ("optimal", "feasible")
    assert abs(res.objective - best) < 1e-6


def test_bb_respects_integrality_and_constraints():
    rng = np.random.default_rng(7)
    A = rng.uniform(0, 1, (6, 6))
    b = rng.uniform(2, 5, 6)
    c = rng.normal(size=6)
    ub = np.full(6, 10.0)
    res = solve_milp(c, A, b, None, None, ub, np.ones(6, bool),
                     max_nodes=500)
    if res.x is not None:
        assert np.abs(res.x - np.round(res.x)).max() < 1e-6
        assert (A @ res.x <= b + 1e-6).all()


def test_bb_mixed_integer():
    """One continuous + one integer variable."""
    # max x0 + x1 st x0 <= 1.5 (cont), x1 <= 2.5 (int) → 1.5 + 2
    c = np.array([-1.0, -1.0])
    A = np.array([[1.0, 0.0], [0.0, 1.0]])
    b = np.array([1.5, 2.5])
    res = solve_milp(c, A, b, None, None, np.array([np.inf, np.inf]),
                     np.array([False, True]), max_nodes=50)
    assert res.status in ("optimal", "feasible")
    assert abs(res.objective - (-3.5)) < 1e-6
